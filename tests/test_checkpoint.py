"""Checkpoint store: save/restore round-trip, atomic publish, restart
resume, async writes, elastic resharding via device_put shardings."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store


def make_tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)), "b": jnp.zeros((16,))},
        "opt": {"m": jnp.ones((8, 16)), "step": jnp.int32(7)},
    }


class TestStore:
    def test_roundtrip(self, tmp_path):
        tree = make_tree()
        store.save(str(tmp_path), 10, tree)
        restored = store.restore(str(tmp_path), 10, tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_step(self, tmp_path):
        tree = make_tree()
        store.save(str(tmp_path), 5, tree)
        store.save(str(tmp_path), 15, tree)
        assert store.latest_step(str(tmp_path)) == 15

    def test_latest_ignores_partial_tmp(self, tmp_path):
        tree = make_tree()
        store.save(str(tmp_path), 5, tree)
        os.makedirs(tmp_path / "step_00000009.tmp")  # crashed writer remnant
        assert store.latest_step(str(tmp_path)) == 5

    def test_latest_none_when_empty(self, tmp_path):
        assert store.latest_step(str(tmp_path)) is None

    def test_async_save(self, tmp_path):
        tree = make_tree()
        t = store.save_async(str(tmp_path), 3, tree)
        t.join()
        assert store.latest_step(str(tmp_path)) == 3

    def test_concurrent_nonblocking_saves_never_corrupt(self, tmp_path):
        """Regression: two non-blocking writers publishing the *same* step
        used to share one ``.tmp`` staging dir — writer B could rmtree the
        dir writer A was mid-rename on.  Each writer now stages under a
        unique tmp name; one rename wins, the loser withdraws, and the
        published checkpoint is always a complete tree."""
        trees = [make_tree(seed=s) for s in range(6)]
        threads = [
            store.save(str(tmp_path), 7, t, blocking=False) for t in trees
        ]
        for t in threads:
            t.join()
        assert store.latest_step(str(tmp_path)) == 7
        # whatever writer won, the tree restores completely and matches one
        # of the racers exactly (no interleaved halves)
        restored = store.restore(str(tmp_path), 7, trees[0])
        leaves = jax.tree.leaves(restored)
        matches = sum(
            all(
                np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(leaves, jax.tree.leaves(t))
            )
            for t in trees
        )
        assert matches == 1
        # no staging remnants survive the race, and the scan ignores any
        leftovers = [d for d in os.listdir(tmp_path) if ".tmp" in d]
        assert leftovers == []

    def test_load_flat_roundtrip(self, tmp_path):
        flat = {
            "meta_seq": np.int64(12),
            "carried_000": np.arange(6, dtype=np.float32).reshape(2, 3),
            "env_140001234": np.ones((4,), np.float32),
        }
        store.save(str(tmp_path), 12, flat)
        back = store.load_flat(str(tmp_path), 12)
        assert set(back) == set(flat)
        for k, v in flat.items():
            np.testing.assert_array_equal(back[k], np.asarray(v))

    def test_shape_mismatch_rejected(self, tmp_path):
        store.save(str(tmp_path), 1, make_tree())
        bad = make_tree()
        bad["params"]["w"] = jnp.zeros((4, 4))
        with pytest.raises(ValueError, match="shape mismatch"):
            store.restore(str(tmp_path), 1, bad)

    def test_restore_with_shardings(self, tmp_path):
        """Elastic path: restore with explicit shardings (single-device mesh
        here; the 256<->512-chip reshard is exercised by the dry-run meshes)."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.distributed.sharding import compat_make_mesh

        tree = make_tree()
        store.save(str(tmp_path), 2, tree)
        mesh = compat_make_mesh((1,), ("data",))
        shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
        restored = store.restore(str(tmp_path), 2, tree, shardings=shardings)
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["w"]), np.asarray(tree["params"]["w"])
        )


class TestTrainRestart:
    def test_crash_and_resume_reproduces_stream(self, tmp_path):
        """Train 30 steps with a crash at 20: resumed losses must continue
        from the checkpoint (deterministic data stream + state restore)."""
        from repro.launch import train

        ckpt = str(tmp_path / "ckpt")
        args = [
            "--arch", "qwen3-0.6b", "--reduced", "--steps", "30",
            "--batch", "2", "--seq", "32", "--ckpt-dir", ckpt,
            "--ckpt-every", "10", "--log-every", "5",
        ]
        crashed = train.main(args + ["--kill-at", "20"])
        assert crashed["crashed_at"] == 20
        assert store.latest_step(ckpt) == 20

        resumed = train.main(args)
        assert resumed["final_loss"] is not None
        straight = train.main(
            [
                "--arch", "qwen3-0.6b", "--reduced", "--steps", "30",
                "--batch", "2", "--seq", "32", "--log-every", "5",
            ]
        )
        # resumed run ends at the same loss as the uninterrupted run
        np.testing.assert_allclose(
            resumed["final_loss"], straight["final_loss"], rtol=1e-4
        )
