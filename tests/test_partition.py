"""Split-replay partition planner: segment-graph extraction, split-execution
equivalence (bitwise vs full-server replay, property-tested over random plans
across registry models), planner dominance over the binary-offloading
endpoints, adaptive re-planning hysteresis, plan-keyed caching, and the
partitioned end-to-end session."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.engine import BoundSegmentedReplay, SegmentedReplayProgram
from repro.core.offload import OffloadSession
from repro.models.cnn_zoo import ZOO
from repro.partition import (
    PLACE_DEVICE,
    PLACE_SERVER,
    AdaptiveReplanner,
    PartitionConfig,
    SegmentGraph,
    SplitPlan,
    evaluate_plan,
    plan_partition,
)

REGISTRY_CASES = {
    "vgg16": dict(scale=0.1, input_size=32),
    "resnet50": dict(scale=0.1, input_size=32),
    "sensor_encoder": dict(scale=0.25, input_size=32, n_blocks=2),
}

MBPS = 1e6 / 8.0


def random_plans(n_ops: int, rng: np.random.Generator, k: int = 6):
    """Sample k random contiguous segmentations with alternating placements."""
    plans = []
    for _ in range(k):
        n_cuts = int(rng.integers(1, min(6, n_ops)))
        cuts = sorted(
            rng.choice(np.arange(1, n_ops), size=n_cuts, replace=False)
        )
        bounds = [0] + [int(c) for c in cuts] + [n_ops]
        place = PLACE_DEVICE if rng.random() < 0.5 else PLACE_SERVER
        placements: list = []
        for lo, hi in zip(bounds, bounds[1:]):
            placements += [place] * (hi - lo)
            place = PLACE_SERVER if place == PLACE_DEVICE else PLACE_DEVICE
        plans.append(SplitPlan.from_placements(placements))
    return plans


@pytest.fixture(scope="module")
def recorded():
    """One replay-locked RRTO session per registry model (real execution)."""
    out = {}
    for name, kwargs in REGISTRY_CASES.items():
        model = ZOO[name](**kwargs)
        sess = OffloadSession(model, "rrto", min_repeats=2)
        sess.load()
        res = None
        for _ in range(5):
            res = sess.infer(*model.example_inputs)
        assert res.mode == "replaying", f"{name} never locked its IOS"
        out[name] = (sess, [np.asarray(o) for o in res.outputs])
    return out


class TestSplitEquivalence:
    @pytest.mark.parametrize("name", sorted(REGISTRY_CASES))
    def test_random_plans_bitwise_identical(self, recorded, name):
        """Acceptance property: for ANY plan, segmented device/server
        execution is bitwise-identical to the full-server replay."""
        sess, ref_outputs = recorded[name]
        calls = sess.client._ios_calls
        env = sess.server.context(sess.client_id).env
        import zlib

        rng = np.random.default_rng(zlib.crc32(name.encode()))
        n_ops = SegmentGraph(calls).n_ops
        plans = random_plans(n_ops, rng) + [
            SplitPlan.full_device(n_ops),
            SplitPlan.from_placements(
                [PLACE_DEVICE] + [PLACE_SERVER] * (n_ops - 1)
            ),
            SplitPlan.from_placements(
                [PLACE_SERVER] * (n_ops - 1) + [PLACE_DEVICE]
            ),
        ]
        inputs = sess.replay_wire_inputs(sess.model.example_inputs)
        for plan in plans:
            prog = SegmentedReplayProgram(calls, plan)
            outs = BoundSegmentedReplay.from_own(prog).execute(inputs, env)
            assert len(outs) == len(ref_outputs)
            for got, want in zip(outs, ref_outputs):
                assert np.array_equal(np.asarray(got), want), (
                    f"{name}: plan {plan.signature()} diverged"
                )

    def test_rebinding_across_clients(self, recorded):
        """A segmented program compiled from one client's calls executes
        correctly when bound to a second client's address space."""
        name = "sensor_encoder"
        model = ZOO[name](**REGISTRY_CASES[name])
        sess_b = OffloadSession(model, "rrto", min_repeats=2, seed=3)
        sess_b.load()
        res = None
        for _ in range(5):
            res = sess_b.infer(*model.example_inputs)
        assert res.mode == "replaying"

        sess_a, _ = recorded[name]
        n_ops = SegmentGraph(sess_a.client._ios_calls).n_ops
        plan = SplitPlan.from_placements(
            [PLACE_DEVICE] * 3 + [PLACE_SERVER] * (n_ops - 3)
        )
        prog = SegmentedReplayProgram(sess_a.client._ios_calls, plan)
        bound = BoundSegmentedReplay.bind(prog, sess_b.client._ios_calls)
        outs = bound.execute(
            sess_b.replay_wire_inputs(model.example_inputs),
            sess_b.server.context(sess_b.client_id).env,
        )
        for got, want in zip(outs, res.outputs):
            assert np.array_equal(np.asarray(got), np.asarray(want))


class TestSegmentGraph:
    def test_cut_tensor_flow(self, recorded):
        """Whatever a suffix needs that isn't an input must be exported by
        the prefix — the dependency closure seals every cut."""
        sess, _ = recorded["resnet50"]
        graph = SegmentGraph(sess.client._ios_calls)
        n = graph.n_ops
        from repro.partition.segments import Segment

        for b in (1, n // 3, n // 2, n - 1):
            prefix, suffix = (
                Segment(0, b, PLACE_DEVICE),
                Segment(b, n, PLACE_SERVER),
            )
            exported = set(graph.segment_outputs(prefix))
            inputs = set(graph.input_tids)
            for tid in graph.segment_inputs(suffix):
                assert tid in exported or tid in inputs

    def test_live_bytes_boundaries(self, recorded):
        sess, _ = recorded["vgg16"]
        graph = SegmentGraph(sess.client._ios_calls)
        live = graph.live_bytes()
        assert len(live) == graph.n_ops + 1
        in_bytes = sum(graph.tensors[t].nbytes for t in graph.input_tids)
        out_bytes = sum(graph.tensors[t].nbytes for t in graph.output_tids)
        assert live[0] == pytest.approx(in_bytes)
        assert live[-1] >= out_bytes
        assert all(b >= 0 for b in live)

    def test_params_never_cross(self, recorded):
        sess, _ = recorded["vgg16"]
        graph = SegmentGraph(sess.client._ios_calls)
        for reads in graph.reads:
            for tid in reads:
                assert not graph.tensors[tid].is_param


class TestPlanner:
    def test_never_worse_than_binary_offloading(self, recorded):
        for name, (sess, _) in recorded.items():
            graph = SegmentGraph(sess.client._ios_calls)
            n = graph.n_ops
            div = sess.model.input_wire_divisor
            for mbps in (0.5, 4.0, 16.0, 64.0, 256.0):
                best = plan_partition(
                    graph, sess.client_device, sess.server_device,
                    mbps * MBPS, input_wire_divisor=div,
                )
                for endpoint in (
                    SplitPlan.full_server(n), SplitPlan.full_device(n)
                ):
                    ev = evaluate_plan(
                        graph, endpoint, sess.client_device,
                        sess.server_device, mbps * MBPS,
                        input_wire_divisor=div,
                    )
                    assert best.seconds <= ev.seconds + 1e-12, (
                        f"{name}@{mbps}Mbps: planner worse than "
                        f"{endpoint.signature()}"
                    )

    def test_interior_split_beats_both_endpoints(self):
        """The bandwidth-bottleneck workload has a regime where a true split
        strictly beats full offload AND device only (partial > binary)."""
        from benchmarks.partition_sweep import run

        rows, checks = run()
        assert checks["planner_never_worse"]
        assert checks["interior_strictly_better"]
        assert any(0 < r.n_device_ops < r.n_ops for r in rows)

    def test_energy_objective(self, recorded):
        sess, _ = recorded["sensor_encoder"]
        graph = SegmentGraph(sess.client._ios_calls)
        cfg = PartitionConfig(objective="energy")
        best = plan_partition(
            graph, sess.client_device, sess.server_device, 16 * MBPS,
            config=cfg,
        )
        assert best.plan.objective == "energy"
        for endpoint in (
            SplitPlan.full_server(graph.n_ops),
            SplitPlan.full_device(graph.n_ops),
        ):
            ev = evaluate_plan(
                graph, endpoint, sess.client_device, sess.server_device,
                16 * MBPS,
            )
            assert best.joules <= ev.joules + 1e-12

    def test_plan_validation(self):
        with pytest.raises(ValueError):
            SplitPlan.from_placements([])
        with pytest.raises(ValueError):
            PartitionConfig(objective="carbon")
        plan = SplitPlan.from_placements(
            [PLACE_DEVICE, PLACE_DEVICE, PLACE_SERVER]
        )
        assert plan.signature() == "D0:2|S2:3"
        assert plan.n_device_ops == 2 and not plan.is_full_server
        assert SplitPlan.full_server(4).is_full_server


@pytest.fixture(scope="module")
def sweep_graph():
    """Full-scale bandwidth-bottleneck workload, recorded analytically."""
    from benchmarks.partition_sweep import record_graph

    return record_graph()


class TestAdaptive:
    def _replanner(self, sweep_graph, **cfg_kwargs):
        graph, device, server, model = sweep_graph
        cfg = PartitionConfig(min_replan_interval_s=0.0, **cfg_kwargs)
        return AdaptiveReplanner(
            graph, device, server, config=cfg,
            input_wire_divisor=model.input_wire_divisor,
        )

    def test_bandwidth_collapse_triggers_replan(self, sweep_graph):
        rp = self._replanner(sweep_graph, bandwidth_ema=1.0)
        rich = rp.initial_plan(128 * MBPS)
        assert not rich.is_full_device  # a fat link offloads the trunk
        swapped = rp.observe(0.2 * MBPS, now=1.0)
        assert swapped is not None and swapped.n_device_ops > rich.n_device_ops
        assert rp.stats.replans == 1

    def test_hysteresis_prevents_thrash(self, sweep_graph):
        # hysteresis=1.0 demands an infinite relative gain: any candidate —
        # even at a collapsed link — must be rejected, never thrashing
        rp = self._replanner(sweep_graph, bandwidth_ema=1.0, hysteresis=1.0)
        rp.initial_plan(128 * MBPS)
        assert rp.observe(0.2 * MBPS, now=1.0) is None
        assert rp.stats.replans == 0
        assert rp.stats.rejected_by_hysteresis >= 1

    def test_mild_wobble_does_not_swap(self, sweep_graph):
        """Near-noise bandwidth variation re-plans to the same cut (signature
        equality short-circuits before any hysteresis comparison)."""
        rp = self._replanner(sweep_graph, bandwidth_ema=1.0)
        first = rp.initial_plan(64 * MBPS)
        for i, mbps in enumerate((60.0, 68.0, 63.0, 66.0)):
            assert rp.observe(mbps * MBPS, now=1.0 + i) is None
        assert rp.stats.replans == 0
        assert rp.current.plan.signature() == first.signature()

    def test_replan_rate_limit(self, sweep_graph):
        graph, device, server, model = sweep_graph
        rp = AdaptiveReplanner(
            graph, device, server,
            config=PartitionConfig(min_replan_interval_s=10.0),
        )
        rp.initial_plan(128 * MBPS, now=0.0)
        considered = rp.stats.plans_considered
        assert rp.observe(0.2 * MBPS, now=0.5) is None   # inside the window
        assert rp.stats.plans_considered == considered
        rp.observe(0.2 * MBPS, now=11.0)                 # window elapsed
        assert rp.stats.plans_considered > considered


class TestPlanKeyedCache:
    def test_cache_keys_on_fingerprint_and_plan(self, recorded):
        from repro.serving.replay_cache import ReplayCache

        sess, _ = recorded["sensor_encoder"]
        calls = sess.client._ios_calls
        server = sess.server
        server.replay_cache = cache = ReplayCache(capacity=8)
        fp = "f" * 8
        n = SegmentGraph(calls).n_ops
        plan_a = SplitPlan.from_placements(
            [PLACE_DEVICE] * 2 + [PLACE_SERVER] * (n - 2)
        )
        plan_b = SplitPlan.from_placements(
            [PLACE_DEVICE] * 4 + [PLACE_SERVER] * (n - 4)
        )
        compiles0 = server.compile_count
        server.prepare_split(calls, plan_a, "c0", fp)
        server.prepare_split(calls, plan_b, "c0", fp)
        assert server.compile_count == compiles0 + 2
        assert f"{fp}|{plan_a.signature()}" in cache
        assert f"{fp}|{plan_b.signature()}" in cache
        # a co-tenant adopting plan_a binds the cached program, no recompile
        assert server.prepare_split(calls, plan_a, "c1", fp) is True
        assert server.compile_count == compiles0 + 2
        server.replay_cache = None


class TestPartitionedSession:
    def test_outputs_match_plain_rrto(self):
        name = "sensor_encoder"
        model = ZOO[name](**REGISTRY_CASES[name])
        plain = OffloadSession(model, "rrto", min_repeats=2, seed=0)
        plain.load()
        split = OffloadSession(
            model, "rrto", min_repeats=2, seed=0,
            partition=PartitionConfig(),
        )
        split.load()
        for _ in range(6):
            want = plain.infer(*model.example_inputs)
            got = split.infer(*model.example_inputs)
            for a, b in zip(got.outputs, want.outputs):
                assert np.array_equal(np.asarray(a), np.asarray(b))
        assert split.client.mode == "replaying"
        assert split.client.replanner is not None

    def test_full_device_plan_needs_no_network(self, recorded):
        """When the planner keeps everything on the device (tiny model), the
        replay phase issues zero RPCs and zero network bytes."""
        import jax.numpy as jnp

        from repro.core.offload import OffloadableModel

        rng = np.random.default_rng(0)
        params = {"w": rng.normal(0, 0.1, (16, 4)).astype(np.float32)}
        model = OffloadableModel(
            "tiny", lambda p, x: [jnp.tanh(x @ p["w"])], params,
            (rng.normal(0, 1, (2, 16)).astype(np.float32),),
        )
        sess = OffloadSession(
            model, "rrto", min_repeats=2, partition=PartitionConfig()
        )
        sess.load()
        res = None
        for _ in range(6):
            res = sess.infer(*model.example_inputs)
        assert res.mode == "replaying"
        assert sess.client.split_plan is not None
        assert sess.client.split_plan.is_full_device
        assert res.rpcs == 0 and res.network_bytes == 0
        from repro.core.energy import STATE_INFERENCE

        assert sess.meter.seconds_by_state.get(STATE_INFERENCE, 0.0) > 0

    def test_split_session_fallback_recovers(self):
        """A DAM-style op-stream change mid-replay must fall back cleanly even
        though split mode never uploaded the inputs, then re-lock."""
        import jax
        import jax.numpy as jnp

        from repro.core.costmodel import GTX_2080TI
        from repro.core.energy import EnergyMeter
        from repro.core.engine import OffloadServer, RRTOClient, SimClock
        from repro.core.flatten import flatten_closed_jaxpr
        from repro.core.intercept import NO_NOISE, JaxprInterceptor
        from repro.core.netsim import indoor_network

        rng = np.random.default_rng(0)
        w = rng.normal(0, 0.1, (8, 8)).astype(np.float32)

        def graph_a(w, x):
            return [jnp.tanh(x @ w) @ w]

        def graph_b(w, x):
            return [jax.nn.relu(x @ w) + x.sum(axis=-1, keepdims=True)]

        x = rng.normal(0, 1, (2, 8)).astype(np.float32)
        ja = flatten_closed_jaxpr(jax.make_jaxpr(lambda xx: graph_a(w, xx))(x))
        jb = flatten_closed_jaxpr(jax.make_jaxpr(lambda xx: graph_b(w, xx))(x))

        clock, meter = SimClock(), EnergyMeter()
        server = OffloadServer(GTX_2080TI, execute=True)
        client = RRTOClient(
            server, indoor_network(), clock, meter, variant="rrto",
            min_repeats=2, partition=PartitionConfig(),
        )
        icp = JaxprInterceptor(client, NO_NOISE)
        addrs_a = icp.upload_params([np.asarray(c) for c in ja.consts])
        addrs_b = icp.upload_params([np.asarray(c) for c in jb.consts])

        for _ in range(4):
            outs_a = icp.run(ja, addrs_a, [x])
        assert client.mode == "replaying"
        assert client.split_plan is not None  # tiny graph -> device plan
        ref_a = np.asarray(jax.jit(lambda xx: graph_a(w, xx))(x)[0])
        np.testing.assert_allclose(np.asarray(outs_a[0]), ref_a, rtol=1e-5)

        icp.run(jb, addrs_b, [x])  # deviate
        assert client.fallbacks >= 1 and client.mode == "recording"
        outs_b = None
        for _ in range(4):
            outs_b = icp.run(jb, addrs_b, [x])
        assert client.mode == "replaying"
        ref_b = np.asarray(jax.jit(lambda xx: graph_b(w, xx))(x)[0])
        np.testing.assert_allclose(np.asarray(outs_b[0]), ref_b, rtol=1e-5)


class TestMultiTenantPlans:
    def test_cotenants_on_different_networks_get_different_cuts(self):
        """Two clients share one IOS but plan at different bandwidths: the
        edge cache keys replay executables on (fingerprint, plan), and each
        client's replay identity includes its own cut."""
        from repro.models.cnn_zoo import make_sensor_encoder
        from repro.serving.multitenant import RRTOEdgeServer

        model = make_sensor_encoder(scale=1.0, input_size=96)
        edge = RRTOEdgeServer(execute=False)
        rich = edge.connect(model, partition=PartitionConfig())
        poor = edge.connect(model, partition=PartitionConfig())
        # starve the second client's radio: ~0.4 Mbps flat
        poor.network.trace_bytes_per_s = np.full(16, 0.4 * MBPS)
        x = model.example_inputs
        for _ in range(6):
            edge.run_round({"c0": x, "c1": x})
        assert all(
            s.client.mode == "replaying" for s in edge.sessions.values()
        )
        k0, k1 = rich.client.replay_key, poor.client.replay_key
        assert k0 is not None and k1 is not None and k0 != k1
        # the poor client keeps the trunk on the device, the rich one cuts
        # after the stem and offloads it
        assert poor.client.split_plan.n_device_ops > (
            rich.client.split_plan.n_device_ops
            if rich.client.split_plan is not None
            else 0
        )
        # the shared cache holds the full program and the per-plan programs
        assert len(edge.cache) >= 2
