"""Operator Sequence Search — unit + hypothesis property tests.

The invariant under test (paper Sec. III-B2): for any log of the form
[arbitrary load/init noise] + [S repeated >= R times (+ partial tail)], the
search recovers exactly S, provided S starts at an HtoD, ends at a DtoH sync
group, and satisfies data-dependency closure.
"""
from __future__ import annotations

import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # hypothesis is optional: only the property-based
    # tests in TestProperties skip; the unit tests above them still run

    def given(**kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(**kwargs):
        return lambda fn: fn

    class st:  # noqa: N801 — stand-in for hypothesis.strategies
        integers = staticmethod(lambda *a, **k: None)

from repro.core.opseq import (
    check_data_dependency,
    fast_check,
    naive_max_repeated_subsequence,
    operator_sequence_search,
)
from repro.core.records import (
    FUNC_D2H,
    FUNC_GET_DEVICE,
    FUNC_H2D,
    FUNC_MALLOC,
    FUNC_SYNC,
    OperatorRecord,
)


def K(name, ins, outs):
    return OperatorRecord(
        f"kernel:{name}", (name, ins, outs), in_buffers=ins, out_buffers=outs
    )


def H2D(dst):
    return OperatorRecord(FUNC_H2D, (dst,), out_buffers=(dst,))


def D2H(src):
    return OperatorRecord(FUNC_D2H, (src,), in_buffers=(src,))


def SYNC():
    return OperatorRecord(FUNC_SYNC, ())


def Q():
    return OperatorRecord(FUNC_GET_DEVICE, ())


PARAM_ADDRS = (900, 901, 902)


def make_load_noise(n_params=3):
    logs = []
    for i in range(n_params):
        logs.append(OperatorRecord(FUNC_MALLOC, (PARAM_ADDRS[i],)))
        logs.append(H2D(PARAM_ADDRS[i]))
    return logs


def make_sequence(rng, n_kernels, n_d2h=1, with_noise=True, seed_addr=1):
    """A coherent inference sequence: chained buffers, query noise, final
    DtoH(s) + syncs."""
    seq = [H2D(seed_addr), SYNC()]
    prev = seed_addr
    outs = []
    for k in range(n_kernels):
        addr = 10 + k
        if with_noise and k % 2 == 0:
            seq.append(Q())
        param = PARAM_ADDRS[int(rng.integers(0, len(PARAM_ADDRS)))]
        seq.append(K(f"op{int(rng.integers(0, 13))}", (prev, param), (addr,)))
        prev = addr
        outs.append(addr)
    for j in range(n_d2h):
        seq.append(D2H(outs[-(j + 1)] if j < len(outs) else prev))
        seq.append(SYNC())
    return seq


class TestUnits:
    def test_fast_check_periodicity(self):
        tags = "xxx" + "HKKDs" * 4
        assert fast_check(tags, 3 + 5 * 3, 5, 3)
        assert not fast_check(tags, 3 + 5 * 3, 5, 5)

    def test_data_dependency_accepts_aligned(self, rng):
        seq = make_sequence(rng, 5)
        logs = make_load_noise() + seq * 3
        start = len(make_load_noise()) + len(seq) * 2
        assert check_data_dependency(logs, start, len(seq))

    def test_data_dependency_rejects_rotation(self, rng):
        seq = make_sequence(rng, 5)
        logs = make_load_noise() + seq * 4
        # rotated window: starts one op into the sequence
        start = len(make_load_noise()) + len(seq) * 2 + 3
        assert not check_data_dependency(logs, start, len(seq))

    def test_search_basic(self, rng):
        seq = make_sequence(rng, 8)
        logs = make_load_noise() + seq * 4
        ios = operator_sequence_search(logs, 3)
        assert ios is not None
        assert list(ios.records) == seq

    def test_search_insufficient_repeats(self, rng):
        seq = make_sequence(rng, 8)
        logs = make_load_noise() + seq * 2
        assert operator_sequence_search(logs, 3) is None

    def test_search_with_init_inference(self, rng):
        seq = make_sequence(rng, 8)
        init = make_sequence(rng, 11, seed_addr=1)  # different first inference
        logs = make_load_noise() + init + seq * 4
        ios = operator_sequence_search(logs, 3)
        assert ios is not None and list(ios.records) == seq

    def test_search_multi_d2h_mid_inference_cut(self, rng):
        seq = make_sequence(rng, 6, n_d2h=3)
        logs = make_load_noise() + seq * 5
        # cut right after the first D2H sync group of the 5th iteration
        first_d2h = next(
            i for i, r in enumerate(seq) if r.func == FUNC_D2H
        )
        cut = len(make_load_noise()) + len(seq) * 4 + first_d2h + 2
        ios = operator_sequence_search(logs[:cut], 3)
        assert ios is not None and list(ios.records) == seq

    def test_naive_merges_iterations(self, rng):
        seq = make_sequence(rng, 4)
        logs = make_load_noise() + seq * 4
        naive = naive_max_repeated_subsequence(logs, 2)
        assert naive is not None and len(naive) == 2 * len(seq)

    def test_num_rpcs_replayed(self, rng):
        seq = make_sequence(rng, 6, n_d2h=3)
        logs = make_load_noise() + seq * 4
        ios = operator_sequence_search(logs, 3)
        assert ios.num_rpcs_replayed == 1 + 3  # 1 HtoD + 3 DtoH


class TestProperties:
    @settings(max_examples=40, deadline=None)
    @given(
        n_kernels=st.integers(2, 40),
        n_repeats=st.integers(3, 6),
        n_d2h=st.integers(1, 3),
        seed=st.integers(0, 2**31 - 1),
        noise_kernels=st.integers(0, 25),
    )
    def test_planted_sequence_recovered(
        self, n_kernels, n_repeats, n_d2h, seed, noise_kernels
    ):
        rng = np.random.default_rng(seed)
        seq = make_sequence(rng, n_kernels, n_d2h=n_d2h)
        logs = make_load_noise()
        if noise_kernels:
            logs += make_sequence(rng, noise_kernels, n_d2h=1)  # init variability
        logs += seq * n_repeats
        ios = operator_sequence_search(logs, 3)
        assert ios is not None
        assert list(ios.records) == seq

    @settings(max_examples=25, deadline=None)
    @given(
        n_kernels=st.integers(2, 30),
        repeats=st.integers(0, 2),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_never_identifies_below_min_repeats(self, n_kernels, repeats, seed):
        rng = np.random.default_rng(seed)
        seq = make_sequence(rng, n_kernels)
        logs = make_load_noise() + seq * repeats
        assert operator_sequence_search(logs, 3) is None

    @settings(max_examples=25, deadline=None)
    @given(
        n_kernels=st.integers(2, 25),
        n_repeats=st.integers(3, 5),
        cut_extra=st.integers(0, 10),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_partial_tail_iteration_is_harmless(
        self, n_kernels, n_repeats, cut_extra, seed
    ):
        """A truncated in-flight iteration after the repeats must not corrupt
        the result (search triggered mid-inference)."""
        rng = np.random.default_rng(seed)
        seq = make_sequence(rng, n_kernels)
        logs = make_load_noise() + seq * n_repeats + seq[: cut_extra % len(seq)]
        ios = operator_sequence_search(logs, 3)
        if ios is not None:
            assert list(ios.records) == seq
