"""Overload protection: SLO classes, token-bucket admission, the three-tier
graceful-degradation ladder, DRR batch-slot fairness, the per-replica circuit
breaker, and the disabled-bitwise-identity pin (``admission=None`` and an
inert controller must both leave the stack byte-identical)."""
from __future__ import annotations

from types import SimpleNamespace

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.netsim import client_stream_seed, poisson_arrivals
from repro.core.offload import OffloadableModel
from repro.distributed.straggler import HedgedRouter, ReplicaModel
from repro.partition.planner import PartitionConfig
from repro.serving import RRTOEdgeServer
from repro.serving.admission import (
    AdmissionController,
    AdmissionRejectedError,
    SLOClass,
    TokenBucket,
    drr_select,
)
from repro.serving.fleet import CircuitBreaker


def make_mlp(seed=0, d_in=16, d_hidden=32, d_out=8):
    rng = np.random.default_rng(seed)
    params = {
        "w1": rng.normal(0, 0.1, (d_in, d_hidden)).astype(np.float32),
        "w2": rng.normal(0, 0.1, (d_hidden, d_out)).astype(np.float32),
    }

    def apply(p, x):
        return [jnp.tanh(x @ p["w1"]) @ p["w2"]]

    x = rng.normal(0, 1, (2, d_in)).astype(np.float32)
    return OffloadableModel(f"mlp{seed}", apply, params, (x,)), x


def zero_capacity_controller(**kwargs) -> AdmissionController:
    """A controller that denies every request: near-zero refill, no burst.
    What happens next is the degradation ladder's choice, not admission's."""
    kwargs.setdefault("rate_hz", 1e-6)
    kwargs.setdefault("burst", 0.0)
    return AdmissionController(**kwargs)


def attach(edge: RRTOEdgeServer, adm: AdmissionController) -> None:
    """Attach a controller to an already-warm edge (the benchmark idiom:
    recording never competes with the measured load for tokens)."""
    adm.bind(server=edge.server, ingress=edge.ingress)
    edge.admission = adm
    edge.batcher.admission = adm
    for cid, sess in edge.sessions.items():
        adm.register(cid, sess.tenant)
        sess.admission = adm


def warm(edge: RRTOEdgeServer, x, spins=4):
    for cid, sess in edge.sessions.items():
        for _ in range(spins):
            if sess.client.mode == "replaying":
                break
            edge.run_round({cid: (x,)})
        assert sess.client.mode == "replaying", cid


class TestTokenBucket:
    def test_refill_is_pure_function_of_time(self):
        tb = TokenBucket(rate_hz=10.0, burst=2.0)
        tb.consume(0.0)
        tb.consume(0.0)
        assert not tb.available(0.0)
        assert not tb.available(0.05)       # only half a token back
        assert tb.available(0.1)            # one full token refilled
        tb.consume(0.1)
        assert not tb.available(0.1)

    def test_burst_caps_the_level(self):
        tb = TokenBucket(rate_hz=100.0, burst=3.0)
        assert tb.available(1e9, n=3.0)
        assert not tb.available(1e9, n=3.5)

    def test_rejects_nonpositive_rate(self):
        with pytest.raises(ValueError):
            TokenBucket(rate_hz=0.0, burst=1.0)


class TestAdmissionController:
    def test_admits_under_capacity(self):
        adm = AdmissionController(rate_hz=100.0, queue_limit=8)
        adm.register("c0", "default")
        d = adm.decide("c0", 0.0)
        assert d.action == "admit"
        assert adm.stats.admitted == 1 and adm.stats.requests == 1

    def test_queue_full_sheds_with_retry_after(self):
        adm = AdmissionController(rate_hz=100.0, queue_limit=2)
        adm.register("c0", "default")
        for _ in range(2):                   # two admitted, never completing
            adm.decide("c0", 0.0)
            adm.note_admitted(0.0, done_at=1e9)
        d = adm.decide("c0", 0.0)
        assert d.action == "shed" and d.reason == "queue full"
        assert d.retry_after_s > 0
        assert adm.stats.queue_rejects == 1
        err = adm.shed_error("c0", d)
        assert isinstance(err, AdmissionRejectedError)
        assert err.retry_after_s == d.retry_after_s and err.queue_depth == 2

    def test_queue_drains_lazily(self):
        adm = AdmissionController(rate_hz=100.0, queue_limit=2)
        adm.register("c0", "default")
        adm.decide("c0", 0.0)
        adm.note_admitted(0.0, done_at=0.5)
        assert adm.queue_depth(0.0) == 1
        assert adm.queue_depth(0.6) == 0     # completion passed

    def test_retry_after_includes_server_backlog(self):
        adm = AdmissionController(rate_hz=10.0, queue_limit=1)
        adm.bind(server=SimpleNamespace(busy_until=5.0))
        assert adm.retry_after(t=1.0, depth=3) >= 4.0

    def test_tenant_share_vs_global_capacity(self):
        """With the global bucket drained, a tenant with its own tokens is
        still denied ('capacity exhausted'); with its own bucket dry and the
        queue too deep to borrow, the reason is the tenant share."""
        classes = {
            "a": SLOClass("a", weight=1.0),
            "b": SLOClass("b", weight=1.0),
        }
        adm = AdmissionController(
            rate_hz=1e-6, burst=2.0, queue_limit=4, borrow_depth=0,
            classes=classes,
        )
        adm.register("ca", "a")
        adm.register("cb", "b")
        # tenant buckets hold >= 1 token each (burst*share floor), the
        # global bucket holds 2: both first requests admit
        assert adm.decide("ca", 0.0).action == "admit"
        assert adm.decide("cb", 0.0).action == "admit"
        # global bucket empty, tenant a's bucket empty too -> tenant share;
        # keep the queue deep so the borrow path stays closed
        adm.note_admitted(0.0, done_at=1e9)
        da = adm.decide("ca", 0.0)
        assert da.action == "shed" and da.reason == "tenant share exhausted"
        assert adm.stats.bucket_rejects >= 1

    def test_work_conserving_borrow(self):
        """A tenant whose own bucket ran dry borrows global spare capacity
        while the queue is shallow — light load admits everything."""
        classes = {
            "a": SLOClass("a", weight=1.0),
            "b": SLOClass("b", weight=1.0),
        }
        adm = AdmissionController(
            rate_hz=1e-6, burst=4.0, queue_limit=8, borrow_depth=4,
            classes=classes,
        )
        adm.register("ca", "a")
        for _ in range(3):                   # > tenant a's ~2-token share
            assert adm.decide("ca", 0.0).action == "admit"
        assert adm.stats.borrowed >= 1

    def test_deadline_scoring(self):
        adm = AdmissionController(rate_hz=100.0)
        adm.note_completion(arrival_t=0.0, done_t=0.1, deadline_t=0.2)
        adm.note_completion(arrival_t=0.0, done_t=0.3, deadline_t=0.2)
        adm.note_completion(arrival_t=0.0, done_t=9.9, deadline_t=None)
        assert adm.stats.deadline_hits == 1
        assert adm.stats.deadline_misses == 1

    def test_admitted_shares_and_weights(self):
        classes = {
            "a": SLOClass("a", weight=3.0),
            "b": SLOClass("b", weight=1.0),
        }
        adm = AdmissionController(rate_hz=1000.0, classes=classes)
        adm.register("ca", "a")
        adm.register("cb", "b")
        for _ in range(3):
            adm.decide("ca", 0.0)
        adm.decide("cb", 0.0)
        assert adm.admitted_shares() == {"a": 0.75, "b": 0.25}
        assert adm.weight_share("a") == 0.75

    def test_register_new_slo_rebuilds_buckets(self):
        adm = AdmissionController(rate_hz=100.0)
        adm.register("c0", "a", slo=SLOClass("a", weight=1.0))
        first = adm._tenant_bucket("a")
        adm.register("c1", "a", slo=SLOClass("a", weight=2.0))
        assert adm._tenant_bucket("a") is not first


class TestDegradationLadder:
    """Every rung of the ladder, end to end through ``OffloadSession.infer``,
    with the property the ladder promises: a response served under overload
    is bitwise-equal to the idle-server response."""

    def _twin_edges(self, partition=None):
        outs = {}
        edges = {}
        for name in ("idle", "loaded"):
            model, x = make_mlp()
            edge = RRTOEdgeServer(execute=True, name=name)
            kwargs = {"min_repeats": 2}
            if partition is not None:
                kwargs["partition"] = partition
            edge.connect(model, client_id="c0", **kwargs)
            warm(edge, x, spins=5)
            outs[name] = np.asarray(edge.run_round({"c0": (x,)})["c0"].outputs[0])
            edges[name] = (edge, x)
        assert np.array_equal(outs["idle"], outs["loaded"])
        return edges

    def test_tier2_device_fallback_bitwise(self):
        """A denied stateless session with deadline headroom degrades to the
        eager device path; outputs stay bitwise-equal to offloaded replay."""
        edges = self._twin_edges()
        idle_edge, x = edges["idle"]
        loaded_edge, _ = edges["loaded"]
        attach(loaded_edge, zero_capacity_controller(
            default_class=SLOClass(deadline_s=1e9),
        ))
        want = idle_edge.run_round({"c0": (x,)})["c0"]
        got = loaded_edge.sessions["c0"].infer(x)
        assert got.mode == "degraded_device"
        assert np.array_equal(
            np.asarray(got.outputs[0]), np.asarray(want.outputs[0])
        )
        assert loaded_edge.admission.stats.degraded_device == 1
        # server never touched: the fallback runs on the client device
        assert got.server_busy_seconds == 0.0

    def test_tier3_shed_when_deadline_cannot_cover_fallback(self):
        """A denied request whose budget cannot even cover the device
        fallback is shed with a typed, actionable rejection."""
        edges = self._twin_edges()
        loaded_edge, x = edges["loaded"]
        attach(loaded_edge, zero_capacity_controller(
            default_class=SLOClass("gold", deadline_s=1e-12),
        ))
        sess = loaded_edge.sessions["c0"]
        with pytest.raises(AdmissionRejectedError) as ei:
            sess.infer(x)
        assert ei.value.retry_after_s > 0
        assert ei.value.client_id == "c0"
        assert loaded_edge.admission.stats.shed == 1
        # the shed is not sticky: detaching the controller restores service
        sess.admission = None
        idle_edge, _ = edges["idle"]
        want = idle_edge.run_round({"c0": (x,)})["c0"]
        got = sess.infer(x)
        assert np.array_equal(
            np.asarray(got.outputs[0]), np.asarray(want.outputs[0])
        )

    def test_tier1_split_session_degrades_plan(self):
        """A denied *split* session degrades its cut device-heavy instead of
        shedding; outputs stay bitwise-equal to the idle twin."""
        edges = self._twin_edges(partition=PartitionConfig())
        idle_edge, x = edges["idle"]
        loaded_edge, _ = edges["loaded"]
        sess = loaded_edge.sessions["c0"]
        assert sess.client.replanner is not None
        attach(loaded_edge, zero_capacity_controller(
            default_class=SLOClass(deadline_s=1e-12),   # tier 2 unaffordable
        ))
        want = idle_edge.run_round({"c0": (x,)})["c0"]
        got = sess.infer(x)
        assert got.mode == "degraded_split"
        assert np.array_equal(
            np.asarray(got.outputs[0]), np.asarray(want.outputs[0])
        )
        assert loaded_edge.admission.stats.degraded_split == 1
        # the degraded plan pushes every movable segment device-side
        assert sess.client.replanner.current.plan.n_device_ops >= 0


class TestReplannerDegrade:
    @pytest.fixture(scope="class")
    def sweep_graph(self):
        from benchmarks.partition_sweep import record_graph

        return record_graph()

    def test_degrade_moves_work_device_side_and_recovers(self, sweep_graph):
        from repro.partition.adaptive import AdaptiveReplanner

        MBPS = 1e6 / 8
        graph, device, server, model = sweep_graph
        rp = AdaptiveReplanner(
            graph, device, server,
            config=PartitionConfig(min_replan_interval_s=0.0),
            input_wire_divisor=model.input_wire_divisor,
        )
        rich = rp.initial_plan(128 * MBPS, now=0.0)
        assert not rich.is_full_device
        degraded = rp.degrade(now=1.0)
        assert degraded is not None
        assert degraded.n_device_ops > rich.n_device_ops
        assert rp.stats.overload_degrades == 1
        # unlike declare_outage, the EMA still reflects the healthy link...
        assert rp.ema_bandwidth == 128 * MBPS
        # ...so the next real sample re-plans straight back to offloading
        restored = rp.observe(128 * MBPS, now=2.0)
        assert restored is not None
        assert restored.n_device_ops < degraded.n_device_ops
        # degrading onto the plan already installed is a no-op
        rp.degrade(now=3.0)
        assert rp.degrade(now=3.0) is None
        assert rp.stats.overload_degrades == 2


class TestDRRSelect:
    def test_capacity_covers_all_passthrough(self):
        members = ["a1", "b1", "a2"]
        got = drr_select(members, 3, lambda m: m[0], lambda t: 1.0, {})
        assert got == members

    def test_weighted_split(self):
        members = [f"a{i}" for i in range(4)] + [f"b{i}" for i in range(4)]
        got = drr_select(members, 3, lambda m: m[0],
                         lambda t: {"a": 2.0, "b": 1.0}[t], {})
        assert sum(1 for m in got if m[0] == "a") == 2
        assert sum(1 for m in got if m[0] == "b") == 1
        # EDF order within a tenant is preserved
        assert [m for m in got if m[0] == "a"] == ["a0", "a1"]

    def test_deficit_alternates_equal_weights(self):
        """Capacity 1, equal weights: the carried deficit alternates the
        winner across rounds — no fixed visiting order starves tenant b."""
        deficits = {}
        winners = []
        for _ in range(4):
            got = drr_select(
                ["a0", "b0"], 1, lambda m: m[0], lambda t: 1.0, deficits
            )
            winners.append(got[0][0])
        assert winners == ["a", "b", "a", "b"]

    def test_emptied_queue_forfeits_deficit(self):
        deficits = {}
        drr_select(["a0", "b0", "b1"], 2, lambda m: m[0],
                   lambda t: 1.0, deficits)
        assert deficits["a"] == 0.0          # a emptied: credit forfeited


class TestCircuitBreaker:
    def test_opens_after_consecutive_failures(self):
        br = CircuitBreaker(failure_threshold=2, cooldown_s=1.0)
        br.record(0.0, failed=True)
        assert br.state == CircuitBreaker.CLOSED
        br.record(0.1, failed=True)
        assert br.state == CircuitBreaker.OPEN and br.opens == 1
        assert not br.allow(0.5)

    def test_success_resets_the_count(self):
        br = CircuitBreaker(failure_threshold=2)
        br.record(0.0, failed=True)
        br.record(0.1, failed=False)
        br.record(0.2, failed=True)
        assert br.state == CircuitBreaker.CLOSED

    def test_halfopen_probe_decides(self):
        br = CircuitBreaker(failure_threshold=1, cooldown_s=1.0)
        br.record(0.0, failed=True)
        assert not br.allow(0.5)
        assert br.allow(1.1)                 # cooldown elapsed: probe admitted
        assert br.state == CircuitBreaker.HALF_OPEN
        br.record(1.2, failed=True)          # bad probe: straight back open
        assert br.state == CircuitBreaker.OPEN and br.opens == 2
        assert br.allow(2.3)
        br.record(2.4, failed=False)         # good probe closes
        assert br.state == CircuitBreaker.CLOSED and br.consecutive_bad == 0

    def test_latency_outlier_counts_as_bad(self):
        br = CircuitBreaker(failure_threshold=1, latency_multiplier=4.0)
        br.record(0.0, failed=False, latency_s=0.5, baseline_s=0.1)
        assert br.state == CircuitBreaker.OPEN
        # no baseline yet -> latency can't be judged -> good
        br2 = CircuitBreaker(failure_threshold=1)
        br2.record(0.0, failed=False, latency_s=9.0, baseline_s=None)
        assert br2.state == CircuitBreaker.CLOSED


class TestRouterHealth:
    def _replicas(self, n=3):
        return [
            ReplicaModel(f"r{i}", 0.01, jitter=lambda _: 0.0)
            for i in range(n)
        ]

    def test_health_none_is_prebreaker_behaviour(self):
        a = HedgedRouter(self._replicas(), min_observations=1)
        b = HedgedRouter(self._replicas(), min_observations=1, health=None)
        picks_a = [a._pick(exclude=-1) for _ in range(6)]
        picks_b = [b._pick(exclude=-1) for _ in range(6)]
        assert picks_a == picks_b

    def test_routes_around_unhealthy_replica(self):
        router = HedgedRouter(
            self._replicas(), min_observations=1,
            health=lambda i: i != 1,
        )
        picks = [router._pick(exclude=-1) for _ in range(6)]
        assert 1 not in picks
        assert set(picks) == {0, 2}

    def test_all_unhealthy_is_soft_not_fatal(self):
        """Saturation everywhere must not escalate to NoHealthyReplicaError:
        the second pass ignores the health signal."""
        router = HedgedRouter(
            self._replicas(), min_observations=1, health=lambda i: False,
        )
        assert router._pick(exclude=-1) in (0, 1, 2)

    def test_observed_median(self):
        router = HedgedRouter(self._replicas(), min_observations=1)
        assert router.observed_median is None
        router._observed.extend([0.1, 0.3, 0.2])
        assert router.observed_median == 0.2


class TestDisabledBitwiseIdentity:
    """The FaultInjector discipline: no controller, and an inert controller,
    must both leave outputs, simulated time and energy byte-identical."""

    def _drive(self, adm_factory):
        model, x = make_mlp()
        edge = RRTOEdgeServer(execute=True)
        for i in range(3):
            edge.connect(model, client_id=f"c{i}", min_repeats=2)
        if adm_factory is not None:
            attach(edge, adm_factory())
        outs, joules = [], []
        for _ in range(6):
            res = edge.run_round({f"c{i}": (x,) for i in range(3)})
            outs.append([np.asarray(res[f"c{i}"].outputs[0]) for i in range(3)])
            joules.append([res[f"c{i}"].joules for i in range(3)])
        return edge, outs, joules

    def test_none_vs_inert_controller(self):
        inert = lambda: AdmissionController(    # noqa: E731
            rate_hz=1e12, queue_limit=10**9, burst=1e12,
            default_class=SLOClass(deadline_s=1e9),
        )
        edge_none, outs_none, joules_none = self._drive(None)
        edge_inert, outs_inert, joules_inert = self._drive(inert)
        assert edge_none.clock.t == edge_inert.clock.t
        assert joules_none == joules_inert
        for round_a, round_b in zip(outs_none, outs_inert):
            for a, b in zip(round_a, round_b):
                assert np.array_equal(a, b)
        # the inert controller really was on the hot path
        assert edge_inert.admission.stats.admitted > 0
        assert edge_inert.admission.stats.shed == 0

    def test_queue_depth_gauges_observable(self):
        """Satellite: ingress wait-queue depth and batcher pending-round
        depth surface as obs gauges once a controller is attached."""
        model, x = make_mlp()
        edge = RRTOEdgeServer(execute=True)
        edge.connect(model, client_id="c0", min_repeats=2)
        attach(edge, AdmissionController(rate_hz=1e6, metrics=edge.metrics))
        for _ in range(4):
            edge.run_round({"c0": (x,)})
        snap = edge.metrics.snapshot()
        assert "queue_depth" in snap and "batcher.pending_depth" in snap
        summary = edge.summary()
        assert summary["queue_depth"] == edge.ingress.queue_depth
        assert summary["pending_depth"] == edge.batcher.pending_depth
        assert summary["admission"]["admitted"] >= 4


class TestDeadlineRoundFormation:
    def _member(self, deadline, tenant="default"):
        cl = SimpleNamespace(deadline_t=deadline, tenant=tenant)
        return (cl, [np.zeros(1, np.float32)])

    def test_edf_orders_by_deadline(self):
        model, _ = make_mlp()
        edge = RRTOEdgeServer(execute=False)
        members = [self._member(3.0), self._member(1.0), self._member(2.0)]
        got = edge.batcher._order_members(list(members))
        assert [m[0].deadline_t for m in got] == [1.0, 2.0, 3.0]

    def test_priority_breaks_deadline_ties(self):
        edge = RRTOEdgeServer(execute=False)
        attach_classes = {
            "gold": SLOClass("gold", priority=2),
            "bronze": SLOClass("bronze", priority=0),
        }
        edge.batcher.admission = AdmissionController(classes=attach_classes)
        members = [
            self._member(1.0, "bronze"),
            self._member(1.0, "gold"),
            self._member(None, "bronze"),    # no deadline sorts last
        ]
        got = edge.batcher._order_members(list(members))
        assert [m[0].tenant for m in got] == ["gold", "bronze", "bronze"]
        assert got[-1][0].deadline_t is None

    def test_passthrough_without_controller_or_deadlines(self):
        edge = RRTOEdgeServer(execute=False)
        members = [self._member(None), self._member(None)]
        got = edge.batcher._order_members(members)
        assert got is members                # the very same list, untouched

    def test_round_capacity_drops_to_solo_replay(self):
        """DRR-dropped members lose their preload and replay solo — every
        member still completes, bitwise-equal to the uncapped control."""
        def drive(capped):
            model, x = make_mlp()
            edge = RRTOEdgeServer(execute=True)
            for i in range(3):
                edge.connect(model, client_id=f"c{i}", min_repeats=2)
            warm(edge, x)
            if capped:
                attach(edge, AdmissionController(
                    rate_hz=1e12, burst=1e12, queue_limit=10**9,
                    default_class=SLOClass(deadline_s=1e9),
                ))
                edge.batcher.round_capacity = 2
            res = edge.run_round({f"c{i}": (x,) for i in range(3)})
            return edge, [np.asarray(res[f"c{i}"].outputs[0]) for i in range(3)]

        edge_capped, outs_capped = drive(capped=True)
        _, outs_free = drive(capped=False)
        for a, b in zip(outs_capped, outs_free):
            assert np.array_equal(a, b)
        assert edge_capped.batcher.solo_replays >= 1


class TestDeterministicArrivalStreams:
    def test_per_client_seed_is_stable_and_distinct(self):
        assert client_stream_seed(0, "c0") == client_stream_seed(0, "c0")
        assert client_stream_seed(0, "c0") != client_stream_seed(0, "c1")
        assert client_stream_seed(0, "c0") != client_stream_seed(1, "c0")

    def test_population_edits_do_not_perturb_streams(self):
        """The paper-benchmark property: one client's arrival schedule is a
        pure function of (seed, client_id), independent of the roster."""
        def schedule(cid):
            return poisson_arrivals(
                50.0, 8, seed=client_stream_seed(7, cid)
            )

        alone = schedule("c3")
        with_roster = [schedule(c) for c in ("c0", "c1", "c2", "c3")][-1]
        assert alone == with_roster
        assert schedule("c2") != schedule("c3")
