"""Per-kernel correctness sweeps: Pallas kernels (interpret mode) and the
chunked portable paths vs the pure-jnp dense oracles, across shapes/dtypes.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention, decode_attention_ref
from repro.kernels.flash_attention import (
    attention_chunked,
    attention_dense,
    flash_attention,
)
from repro.kernels.rmsnorm import rmsnorm, rmsnorm_ref
from repro.kernels.ssm_scan import (
    gated_scan,
    gated_scan_ref,
    ssm_scan,
    ssm_scan_ref,
    ssm_step_ref,
)

TOL = dict(rtol=2e-4, atol=2e-4)


class TestFlashAttention:
    @pytest.mark.parametrize(
        "b,sq,sk,hq,hkv,d,causal,window",
        [
            (2, 128, 128, 4, 2, 64, True, None),
            (1, 256, 256, 8, 8, 128, True, 128),
            (1, 128, 384, 4, 1, 64, True, None),
            (2, 128, 128, 4, 4, 64, False, None),
            (1, 256, 256, 2, 2, 128, True, None),
        ],
    )
    def test_pallas_vs_dense(self, rng, b, sq, sk, hq, hkv, d, causal, window):
        q = rng.normal(0, 1, (b, sq, hq, d)).astype(np.float32)
        k = rng.normal(0, 1, (b, sk, hkv, d)).astype(np.float32)
        v = rng.normal(0, 1, (b, sk, hkv, d)).astype(np.float32)
        qoff = sk - sq
        ref = attention_dense(q, k, v, causal=causal, window=window, q_offset=qoff)
        out = flash_attention(
            q, k, v, causal=causal, window=window, q_offset=qoff, interpret=True
        )
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)

    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    def test_dtypes(self, rng, dtype):
        import jax.numpy as jnp

        q = rng.normal(0, 1, (1, 128, 4, 64)).astype(dtype)
        k = rng.normal(0, 1, (1, 128, 2, 64)).astype(dtype)
        v = rng.normal(0, 1, (1, 128, 2, 64)).astype(dtype)
        ref = attention_dense(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
        out = flash_attention(q, k, v, interpret=True)
        tol = 2e-2 if dtype == "bfloat16" else 2e-4
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            rtol=tol, atol=tol,
        )

    def test_chunked_nondivisible_kv(self, rng):
        # whisper cross-attention case: 1500 keys, chunk 1024
        q = rng.normal(0, 1, (1, 64, 4, 32)).astype(np.float32)
        k = rng.normal(0, 1, (1, 1500, 4, 32)).astype(np.float32)
        v = rng.normal(0, 1, (1, 1500, 4, 32)).astype(np.float32)
        ref = attention_dense(q, k, v, causal=False)
        out = attention_chunked(q, k, v, causal=False, kv_chunk=1024)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


class TestDecodeAttention:
    @pytest.mark.parametrize(
        "b,s,hq,hkv,d,window",
        [
            (2, 1024, 8, 2, 64, None),
            (1, 2048, 16, 8, 128, None),
            (2, 1024, 4, 4, 64, 256),
            (1, 512, 8, 1, 64, None),
            (3, 512, 40, 40, 64, None),     # MHA-style
        ],
    )
    def test_pallas_vs_ref(self, rng, b, s, hq, hkv, d, window):
        import jax.numpy as jnp

        q = rng.normal(0, 1, (b, hq, d)).astype(np.float32)
        kc = rng.normal(0, 1, (b, s, hkv, d)).astype(np.float32)
        vc = rng.normal(0, 1, (b, s, hkv, d)).astype(np.float32)
        kv_len = jnp.asarray(
            (np.arange(b) * 97 % (s - 8) + 8).astype(np.int32)
        )
        ref = decode_attention_ref(q, kc, vc, kv_len, window=window)
        out = decode_attention(q, kc, vc, kv_len, window=window, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


class TestRMSNorm:
    @pytest.mark.parametrize(
        "shape,offset", [((4, 128, 256), 0.0), ((2, 64, 512), 1.0), ((3, 7, 96), 0.0)]
    )
    def test_pallas_vs_ref(self, rng, shape, offset):
        x = rng.normal(0, 1, shape).astype(np.float32)
        s = rng.normal(0, 0.1, shape[-1:]).astype(np.float32)
        ref = rmsnorm_ref(x, s, offset=offset)
        out = rmsnorm(x, s, offset=offset, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6, atol=1e-6)


class TestSSMScan:
    def _naive(self, x, dt, A, Bm, Cm, D):
        b, s, h, p = x.shape
        g, n = Bm.shape[2], Bm.shape[3]
        rep = h // g
        hst = np.zeros((b, h, n, p), np.float64)
        ys = np.zeros_like(x, dtype=np.float64)
        for t in range(s):
            for bb in range(b):
                for hh in range(h):
                    gg = hh // rep
                    dA = np.exp(dt[bb, t, hh] * A[hh])
                    hst[bb, hh] = dA * hst[bb, hh] + dt[bb, t, hh] * np.outer(
                        Bm[bb, t, gg], x[bb, t, hh]
                    )
                    ys[bb, t, hh] = Cm[bb, t, gg] @ hst[bb, hh] + D[hh] * x[bb, t, hh]
        return ys, hst

    @pytest.mark.parametrize(
        "b,s,h,p,g,n,chunk",
        [(2, 64, 4, 8, 2, 16, 16), (1, 96, 8, 16, 1, 32, 32), (1, 48, 2, 8, 2, 8, 16)],
    )
    def test_chunked_vs_naive(self, rng, b, s, h, p, g, n, chunk):
        x = rng.normal(0, 1, (b, s, h, p)).astype(np.float32)
        dt = (np.abs(rng.normal(0.5, 0.2, (b, s, h))) + 0.01).astype(np.float32)
        A = -np.abs(rng.normal(1, 0.3, (h,))).astype(np.float32)
        Bm = rng.normal(0, 1, (b, s, g, n)).astype(np.float32)
        Cm = rng.normal(0, 1, (b, s, g, n)).astype(np.float32)
        D = rng.normal(0, 1, (h,)).astype(np.float32)
        y_naive, h_naive = self._naive(x, dt, A, Bm, Cm, D)
        y_ref, h_ref = ssm_scan_ref(x, dt, A, Bm, Cm, D, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y_ref), y_naive, rtol=3e-4, atol=3e-4)
        y_pl, h_pl = ssm_scan(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=True)
        np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref), **TOL)
        np.testing.assert_allclose(np.asarray(h_pl), np.asarray(h_ref), **TOL)

    def test_step_matches_scan(self, rng):
        b, s, h, p, g, n = 2, 32, 4, 8, 2, 16
        x = rng.normal(0, 1, (b, s, h, p)).astype(np.float32)
        dt = (np.abs(rng.normal(0.5, 0.2, (b, s, h))) + 0.01).astype(np.float32)
        A = -np.abs(rng.normal(1, 0.3, (h,))).astype(np.float32)
        Bm = rng.normal(0, 1, (b, s, g, n)).astype(np.float32)
        Cm = rng.normal(0, 1, (b, s, g, n)).astype(np.float32)
        D = rng.normal(0, 1, (h,)).astype(np.float32)
        y_scan, h_scan = ssm_scan_ref(x, dt, A, Bm, Cm, D, chunk=8)
        hst = np.zeros((b, h, n, p), np.float32)
        for t in range(s):
            y_t, hst = ssm_step_ref(
                x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], D, hst
            )
        np.testing.assert_allclose(
            np.asarray(y_t), np.asarray(y_scan[:, -1]), rtol=2e-3, atol=2e-3
        )
        np.testing.assert_allclose(np.asarray(hst), np.asarray(h_scan), rtol=2e-3, atol=2e-3)

    def test_gated_form_mlstm(self, rng):
        b, s, h, p, g, n, chunk = 2, 48, 4, 8, 4, 8, 16
        x = rng.normal(0, 1, (b, s, h, p)).astype(np.float32)
        ld = -np.abs(rng.normal(0.3, 0.2, (b, s, h))).astype(np.float32)
        gi = np.abs(rng.normal(0.8, 0.3, (b, s, h))).astype(np.float32)
        Bm = rng.normal(0, 1, (b, s, g, n)).astype(np.float32)
        Cm = rng.normal(0, 1, (b, s, g, n)).astype(np.float32)
        y_ref, h_ref = gated_scan_ref(x, ld, gi, Bm, Cm, None, chunk=chunk)
        y_pl, h_pl = gated_scan(x, ld, gi, Bm, Cm, None, chunk=chunk, interpret=True)
        np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref), **TOL)
        np.testing.assert_allclose(np.asarray(h_pl), np.asarray(h_ref), **TOL)

    def test_nondivisible_seq_padding(self, rng):
        b, s, h, p, g, n = 1, 17, 2, 4, 1, 8
        x = rng.normal(0, 1, (b, s, h, p)).astype(np.float32)
        dt = (np.abs(rng.normal(0.5, 0.2, (b, s, h))) + 0.01).astype(np.float32)
        A = -np.abs(rng.normal(1, 0.3, (h,))).astype(np.float32)
        Bm = rng.normal(0, 1, (b, s, g, n)).astype(np.float32)
        Cm = rng.normal(0, 1, (b, s, g, n)).astype(np.float32)
        D = rng.normal(0, 1, (h,)).astype(np.float32)
        y_naive, h_naive = self._naive(x, dt, A, Bm, Cm, D)
        y, h_f = ssm_scan(x, dt, A, Bm, Cm, D, chunk=8)
        np.testing.assert_allclose(np.asarray(y), y_naive, rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(np.asarray(h_f), h_naive, rtol=3e-4, atol=3e-4)
