"""Network + energy models: trace statistics match the paper's measured
environments, RPC timing monotonicity, energy integration."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.energy import (
    STATE_COMM,
    STATE_INFERENCE,
    STATE_STANDBY,
    EnergyMeter,
    PowerModel,
)
from repro.core.netsim import get_network, indoor_network, outdoor_network


class TestNetsim:
    def test_trace_means_match_paper(self):
        assert indoor_network().mean_mbps == pytest.approx(93.0, abs=3.0)
        assert outdoor_network().mean_mbps == pytest.approx(73.0, abs=3.0)

    def test_outdoor_more_variable(self):
        i = indoor_network().trace_bytes_per_s
        o = outdoor_network().trace_bytes_per_s
        assert o.std() / o.mean() > i.std() / i.mean()

    def test_deterministic(self):
        a = indoor_network(seed=0).trace_bytes_per_s
        b = indoor_network(seed=0).trace_bytes_per_s
        np.testing.assert_array_equal(a, b)

    def test_rpc_time_monotone_in_payload(self):
        net = indoor_network()
        t1 = net.rpc_time(1e3, 64, 0.0)
        t2 = net.rpc_time(1e6, 64, 0.0)
        assert t2 > t1

    def test_unknown_env_raises(self):
        with pytest.raises(ValueError):
            get_network("underwater")


class TestEnergy:
    def test_power_states_match_tab2(self):
        pm = PowerModel()
        assert pm.power(STATE_INFERENCE) == 13.35
        assert pm.power(STATE_COMM) == 4.25
        assert pm.power(STATE_STANDBY) == 4.04

    def test_integration(self):
        m = EnergyMeter()
        m.add(STATE_INFERENCE, 2.0)
        m.add(STATE_COMM, 1.0)
        assert m.joules == pytest.approx(2 * 13.35 + 4.25)
        assert m.mean_watts == pytest.approx((2 * 13.35 + 4.25) / 3)

    def test_since_delta(self):
        m = EnergyMeter()
        m.add(STATE_COMM, 1.0)
        snap = m.snapshot()
        m.add(STATE_COMM, 2.0)
        assert m.since(snap).joules == pytest.approx(2 * 4.25)

    def test_negative_duration_rejected(self):
        with pytest.raises(ValueError):
            EnergyMeter().add(STATE_COMM, -1.0)
