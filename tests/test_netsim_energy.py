"""Network + energy models: trace statistics match the paper's measured
environments, RPC timing monotonicity, energy integration, shared-ingress
fair-share edge cases, and energy accounting when device and server segments
interleave (split replay)."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.energy import (
    STATE_COMM,
    STATE_CONTROL,
    STATE_INFERENCE,
    STATE_STANDBY,
    EnergyMeter,
    PowerModel,
)
from repro.core.netsim import (
    ServerIngress,
    get_network,
    indoor_network,
    outdoor_network,
)


class TestNetsim:
    def test_trace_means_match_paper(self):
        assert indoor_network().mean_mbps == pytest.approx(93.0, abs=3.0)
        assert outdoor_network().mean_mbps == pytest.approx(73.0, abs=3.0)

    def test_outdoor_more_variable(self):
        i = indoor_network().trace_bytes_per_s
        o = outdoor_network().trace_bytes_per_s
        assert o.std() / o.mean() > i.std() / i.mean()

    def test_deterministic(self):
        a = indoor_network(seed=0).trace_bytes_per_s
        b = indoor_network(seed=0).trace_bytes_per_s
        np.testing.assert_array_equal(a, b)

    def test_rpc_time_monotone_in_payload(self):
        net = indoor_network()
        t1 = net.rpc_time(1e3, 64, 0.0)
        t2 = net.rpc_time(1e6, 64, 0.0)
        assert t2 > t1

    def test_unknown_env_raises(self):
        with pytest.raises(ValueError):
            get_network("underwater")


class TestEnergy:
    def test_power_states_match_tab2(self):
        pm = PowerModel()
        assert pm.power(STATE_INFERENCE) == 13.35
        assert pm.power(STATE_COMM) == 4.25
        assert pm.power(STATE_STANDBY) == 4.04

    def test_integration(self):
        m = EnergyMeter()
        m.add(STATE_INFERENCE, 2.0)
        m.add(STATE_COMM, 1.0)
        assert m.joules == pytest.approx(2 * 13.35 + 4.25)
        assert m.mean_watts == pytest.approx((2 * 13.35 + 4.25) / 3)

    def test_since_delta(self):
        m = EnergyMeter()
        m.add(STATE_COMM, 1.0)
        snap = m.snapshot()
        m.add(STATE_COMM, 2.0)
        assert m.since(snap).joules == pytest.approx(2 * 4.25)

    def test_negative_duration_rejected(self):
        with pytest.raises(ValueError):
            EnergyMeter().add(STATE_COMM, -1.0)


class TestServerIngress:
    def test_single_client_gets_full_capacity(self):
        ing = ServerIngress(capacity_bytes_per_s=8e6, active_clients=1)
        assert ing.share() == 8e6
        net = indoor_network(0)
        net.ingress = ing
        # the share (8 MB/s) is below the ~11.6 MB/s radio: ingress-bound
        assert net.transfer_time(8e6, 0.0) == pytest.approx(1.0, rel=1e-6)

    def test_degenerate_client_counts(self):
        ing = ServerIngress(capacity_bytes_per_s=10e6)
        ing.active_clients = 0          # idle round: share must not divide by 0
        assert ing.share() == 10e6
        ing.active_clients = -3         # defensive: treated like idle
        assert ing.share() == 10e6

    def test_zero_bandwidth_interval_is_finite(self):
        """A fully obstructed interval (or a zero-capacity ingress) stalls
        transfers for a long-but-finite time instead of dividing by zero."""
        ing = ServerIngress(capacity_bytes_per_s=0.0, active_clients=4)
        net = indoor_network(0)
        net.ingress = ing
        dt = net.transfer_time(1e3, 0.0)
        assert np.isfinite(dt) and dt > 1e3  # >1000 s for 1 KB: stalled
        net2 = indoor_network(0)
        net2.trace_bytes_per_s = np.zeros(8)
        assert np.isfinite(net2.transfer_time(1e3, 0.0))

    def test_join_leave_mid_round(self):
        """The fair share tracks joins and leaves between transfers, and the
        aggregate byte counter keeps accumulating across both directions."""
        ing = ServerIngress(capacity_bytes_per_s=10e6)
        net = indoor_network(0)
        net.ingress = ing
        ing.active_clients = 1
        t1 = net.transfer_time(1e6, 0.0)
        ing.active_clients = 10          # nine clients join mid-round
        t10 = net.transfer_time(1e6, 0.0)
        ing.active_clients = 2           # eight leave
        t2 = net.transfer_time(1e6, 0.0)
        assert t10 > t2 > t1
        assert t10 == pytest.approx(1e6 / (10e6 / 10), rel=1e-6)
        assert ing.bytes_total == pytest.approx(3e6)


class TestInterleavedEnergy:
    """EnergyMeter accounting when device and server segments interleave."""

    def test_meter_matches_schedule_breakdown(self):
        """The split schedule's phase integral equals hand-integrated power:
        device compute at inference draw, un-overlapped transfers at comm
        draw, the server-segment wait at standby draw — and the three phases
        tile the body timeline exactly (overlapped uplink is billed inside
        the inference envelope, never double-counted)."""
        from benchmarks.partition_sweep import record_graph
        from repro.partition import (
            PLACE_DEVICE,
            PLACE_SERVER,
            ConstantLink,
            SplitPlan,
            compute_schedule,
        )

        graph, device, server, model = record_graph()
        n = graph.n_ops
        pm = PowerModel()
        plans = [
            SplitPlan.from_placements(
                [PLACE_DEVICE] * 2
                + [PLACE_SERVER] * (n - 4)
                + [PLACE_DEVICE] * 2
            ),
            # a mid-trunk cut: residual skip tensors produced mid-segment
            # force genuinely overlapped uplink
            SplitPlan.from_placements(
                [PLACE_DEVICE] * (n // 2) + [PLACE_SERVER] * (n - n // 2)
            ),
        ]
        for plan in plans:
            sched = compute_schedule(
                graph, plan, device, server, ConstantLink(4e6, 1e-4)
            )
            meter = EnergyMeter(pm)
            meter.add(STATE_INFERENCE, sched.device_seconds)
            meter.add(STATE_COMM, sched.radio_only_seconds)
            meter.add(STATE_STANDBY, sched.wait_seconds)
            assert sched.device_seconds > 0 and sched.server_seconds > 0
            assert sched.joules(pm) == pytest.approx(
                meter.joules
                + pm.power(STATE_COMM) * sched.output_downlink_seconds
            )
            # the three phases tile the body wall time exactly
            assert meter.total_seconds == pytest.approx(
                sched.body_seconds, rel=1e-9
            )

    def test_overlapped_uplink_not_double_billed(self):
        """A cut right after a long device prefix ships boundary tensors
        while later device ops still run: comm overlaps compute, and the
        billable radio-only time shrinks accordingly."""
        from benchmarks.partition_sweep import record_graph
        from repro.partition import (
            PLACE_DEVICE,
            PLACE_SERVER,
            ConstantLink,
            SplitPlan,
            compute_schedule,
        )

        graph, device, server, _ = record_graph()
        n = graph.n_ops
        plan = SplitPlan.from_placements(
            [PLACE_DEVICE] * (n // 2) + [PLACE_SERVER] * (n - n // 2)
        )
        sched = compute_schedule(
            graph, plan, device, server, ConstantLink(64e6, 1e-4)
        )
        assert sched.overlap_seconds > 0
        assert sched.radio_only_seconds == pytest.approx(
            sched.comm_seconds - sched.overlap_seconds
        )
        assert sched.radio_only_seconds >= 0

    def test_partitioned_session_meter_covers_timeline(self):
        """Every simulated second of a split session is attributed to exactly
        one power state — the meter total equals the clock."""
        from repro.core.offload import OffloadSession
        from repro.models.cnn_zoo import make_sensor_encoder
        from repro.partition import PartitionConfig

        model = make_sensor_encoder(scale=0.25, input_size=32, n_blocks=2)
        sess = OffloadSession(
            model, "rrto", min_repeats=2, partition=PartitionConfig()
        )
        sess.load()
        for _ in range(6):
            sess.infer(*model.example_inputs)
        assert sess.client.mode == "replaying"
        assert sess.meter.total_seconds == pytest.approx(
            sess.clock.t, rel=1e-9
        )
        by_state = sess.meter.seconds_by_state
        assert by_state.get(STATE_INFERENCE, 0.0) > 0   # device segments ran
        assert by_state.get(STATE_CONTROL, 0.0) > 0
