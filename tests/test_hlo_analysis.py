"""Trip-count-weighted HLO analysis: validated against a compiled module with
a known layer-scan structure (flops must scale with the scan trip count, which
XLA's own cost_analysis misses)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze_hlo, parse_module


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


class TestWeightedAnalysis:
    def test_scan_trip_count_scaling(self):
        d, L = 64, 12
        w = jnp.ones((L, d, d), jnp.float32) * 0.01
        x = jnp.ones((8, d), jnp.float32)

        def stack(x, w):
            return jax.lax.scan(lambda h, wi: (jnp.tanh(h @ wi), None), x, w)[0]

        hlo = _compile(stack, x, w).as_text()
        a = analyze_hlo(hlo)
        expected_dot = 2 * 8 * d * d * L
        assert a["dot_flops"] == pytest.approx(expected_dot, rel=0.05)

    def test_unrolled_matches_scan(self):
        d, L = 32, 6
        w = jnp.ones((L, d, d), jnp.float32) * 0.01
        x = jnp.ones((4, d), jnp.float32)

        def scanned(x, w):
            return jax.lax.scan(lambda h, wi: (h @ wi, None), x, w)[0]

        def unrolled(x, w):
            for i in range(L):
                x = x @ w[i]
            return x

        a = analyze_hlo(_compile(scanned, x, w).as_text())
        b = analyze_hlo(_compile(unrolled, x, w).as_text())
        assert a["dot_flops"] == pytest.approx(b["dot_flops"], rel=0.05)

    def test_collectives_detected(self):
        from jax.sharding import PartitionSpec as P

        from repro.distributed.sharding import compat_make_mesh, get_shard_map

        mesh = compat_make_mesh((1,), ("d",))
        shard_map = get_shard_map()

        f = shard_map(
            lambda v: jax.lax.psum(v, "d"), mesh=mesh,
            in_specs=P(None), out_specs=P(None),
        )
        hlo = _compile(f, jnp.ones((128,), jnp.float32)).as_text()
        a = analyze_hlo(hlo)
        assert a["collective_bytes"] >= 128 * 4

    def test_parse_module_structure(self):
        hlo = _compile(lambda x: jnp.tanh(x) @ x.T, jnp.ones((8, 8))).as_text()
        comps = parse_module(hlo)
        assert len(comps) >= 1
        total_instrs = sum(len(v) for v in comps.values())
        assert total_instrs > 2
