"""Multi-tenant replay-cache serving: fingerprint stability across clients,
cache-hit adoption skipping the recording phase, LRU eviction, cross-client
batched replay correctness, per-client state isolation, and single-client
equivalence with the pre-refactor path."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.netsim import ServerIngress, indoor_network
from repro.core.offload import OffloadableModel, OffloadSession
from repro.core.opseq import ios_fingerprint
from repro.serving.multitenant import RRTOEdgeServer
from repro.serving.replay_cache import ReplayCache


def make_mlp(seed=0, d_in=16, d_hidden=32, d_out=8):
    rng = np.random.default_rng(seed)
    params = {
        "w1": rng.normal(0, 0.1, (d_in, d_hidden)).astype(np.float32),
        "w2": rng.normal(0, 0.1, (d_hidden, d_out)).astype(np.float32),
    }

    def apply(p, x):
        return [jnp.tanh(x @ p["w1"]) @ p["w2"]]

    x = rng.normal(0, 1, (2, d_in)).astype(np.float32)
    return OffloadableModel(f"mlp{seed}", apply, params, (x,)), x


def make_deep_mlp(seed=0, d=16):
    rng = np.random.default_rng(seed)
    params = {
        "w1": rng.normal(0, 0.1, (d, d)).astype(np.float32),
        "w2": rng.normal(0, 0.1, (d, d)).astype(np.float32),
        "w3": rng.normal(0, 0.1, (d, d)).astype(np.float32),
    }

    def apply(p, x):
        h = jnp.tanh(x @ p["w1"])
        h = jax.nn.relu(h @ p["w2"])
        return [h @ p["w3"]]

    x = rng.normal(0, 1, (2, d)).astype(np.float32)
    return OffloadableModel(f"deep{seed}", apply, params, (x,)), x


class TestFingerprint:
    def test_stable_across_clients(self):
        """Two independent sessions (own interceptor, own allocator) running
        the same model must produce the same IOS fingerprint."""
        ios = []
        for seed in (0, 1):  # different network seeds, same model structure
            model, x = make_mlp()
            sess = OffloadSession(
                model, "rrto", min_repeats=3, seed=seed, execute=False
            )
            sess.load()
            for _ in range(5):
                sess.infer(x)
            assert sess.client.ios is not None
            ios.append(sess.client.ios)
        assert ios_fingerprint(ios[0].records) == ios_fingerprint(ios[1].records)

    def test_differs_across_models(self):
        fps = []
        for maker in (make_mlp, make_deep_mlp):
            model, x = maker()
            sess = OffloadSession(model, "rrto", min_repeats=3, execute=False)
            sess.load()
            for _ in range(5):
                sess.infer(x)
            fps.append(ios_fingerprint(sess.client.ios.records))
        assert fps[0] != fps[1]

    def test_param_values_do_not_matter(self):
        """Same architecture, different weights -> same fingerprint (the
        structure, not the data, is the content address)."""
        fps = []
        for seed in (0, 7):
            model, x = make_mlp(seed=seed)
            sess = OffloadSession(model, "rrto", min_repeats=3, execute=False)
            sess.load()
            for _ in range(5):
                sess.infer(x)
            fps.append(ios_fingerprint(sess.client.ios.records))
        assert fps[0] == fps[1]


class TestCacheAdoption:
    def test_late_client_skips_recording(self):
        """A client joining after the cache is warm adopts the IOS after a
        single recorded inference instead of min_repeats of them."""
        model, x = make_mlp()
        edge = RRTOEdgeServer(execute=True)
        first = edge.connect(model, min_repeats=3)
        for _ in range(3):
            edge.run_round({"c0": (x,)})
        assert first.client.mode == "replaying"
        assert not first.client.cache_adopted

        late = edge.connect(model, min_repeats=3)
        edge.run_round({"c0": (x,), "c1": (x,)})
        assert late.client.mode == "replaying"
        assert late.client.cache_adopted
        rec = [r for r in late.history if r.mode == "recording"]
        assert len(rec) == 1  # one recorded inference, not three

    def test_compile_exactly_once(self):
        model, x = make_mlp()
        edge = RRTOEdgeServer(execute=True)
        edge.connect(model)
        for _ in range(3):
            edge.run_round({"c0": (x,)})
        for i in range(3):
            edge.connect(model)
            edge.run_round({f"c{j}": (x,) for j in range(i + 2)})
        assert edge.compile_count == 1
        assert edge.cache.stats.hits == 3  # one bind per adopting client

    def test_batched_replay_outputs_correct(self):
        model, x = make_mlp()
        ref = np.asarray(jax.jit(model.apply)(model.params, x)[0])
        edge = RRTOEdgeServer(execute=True)
        for _ in range(3):
            edge.connect(model)
        all_ids = list(edge.sessions)
        for _ in range(4):
            results = edge.run_round({c: (x,) for c in all_ids})
        assert all(
            s.client.mode == "replaying" for s in edge.sessions.values()
        )
        for r in results.values():
            np.testing.assert_allclose(
                np.asarray(r.outputs[0]), ref, rtol=1e-5, atol=1e-5
            )
        assert edge.batcher.batches_executed >= 1
        assert max(edge.batcher.batch_sizes) == 3

    def test_vmap_batch_bitwise_equals_loop(self):
        """Shared-param co-tenants execute as one true vmap-batched call;
        the outputs must be bitwise identical to the per-client loop."""
        model, _ = make_mlp()
        rng = np.random.default_rng(5)
        per_client = {f"c{i}": rng.normal(0, 1, (2, 16)).astype(np.float32)
                      for i in range(3)}

        def run(enable_vmap):
            edge = RRTOEdgeServer(execute=True)
            edge.batcher.enable_vmap = enable_vmap
            for _ in range(3):
                edge.connect(model)
            for _ in range(5):
                results = edge.run_round(
                    {c: (x,) for c, x in per_client.items()}
                )
            return results, edge

        vmapped, edge_v = run(True)
        looped, edge_l = run(False)
        assert edge_v.batcher.vmap_batches >= 1
        assert edge_l.batcher.vmap_batches == 0
        for c in per_client:
            np.testing.assert_array_equal(
                np.asarray(vmapped[c].outputs[0]),
                np.asarray(looped[c].outputs[0]),
            )

    def test_vmap_disabled_falls_back_to_loop(self):
        model, x = make_mlp()
        edge = RRTOEdgeServer(execute=True)
        edge.batcher.enable_vmap = False
        for _ in range(3):
            edge.connect(model)
        ids = list(edge.sessions)
        for _ in range(5):
            results = edge.run_round({c: (x,) for c in ids})
        assert edge.batcher.vmap_batches == 0
        assert edge.batcher.batches_executed >= 1
        ref = np.asarray(jax.jit(model.apply)(model.params, x)[0])
        for r in results.values():
            np.testing.assert_allclose(
                np.asarray(r.outputs[0]), ref, rtol=1e-5, atol=1e-5
            )

    def test_per_client_params_isolated(self):
        """Clients with the same architecture but different weights share one
        compiled program yet keep their own parameter memory."""
        m0, x = make_mlp(seed=0)
        m1, _ = make_mlp(seed=7)
        edge = RRTOEdgeServer(execute=True)
        edge.connect(m0)
        edge.connect(m1)
        for _ in range(4):
            results = edge.run_round({"c0": (x,), "c1": (x,)})
        assert edge.compile_count == 1  # same fingerprint, one program
        for model, cid in ((m0, "c0"), (m1, "c1")):
            ref = np.asarray(jax.jit(model.apply)(model.params, x)[0])
            np.testing.assert_allclose(
                np.asarray(results[cid].outputs[0]), ref, rtol=1e-5, atol=1e-5
            )


class TestPaddedVmapWidths:
    def test_padded_widths_reuse_executables(self):
        """Batch widths pad to the next power of two: a width-3 round reuses
        the width-4 executable a width-4 round compiled (O(log N) compiles
        per fingerprint instead of one per width), with correct outputs."""
        model, x = make_mlp()
        ref = np.asarray(jax.jit(model.apply)(model.params, x)[0])
        edge = RRTOEdgeServer(execute=True)
        for _ in range(4):
            edge.connect(model)
        ids = list(edge.sessions)
        for _ in range(4):
            edge.run_round({c: (x,) for c in ids})
        assert all(
            s.client.mode == "replaying" for s in edge.sessions.values()
        )
        edge.run_round({c: (x,) for c in ids})      # width 4 -> #vmap4
        assert any("#vmap4" in k for k in edge.cache.fingerprints)
        compiles = edge.batcher.vmap_compiles
        avoided = edge.batcher.vmap_compiles_avoided
        results = edge.run_round({c: (x,) for c in ids[:3]})  # width 3 -> pads to 4
        assert edge.batcher.vmap_compiles == compiles       # no new build
        assert edge.batcher.vmap_compiles_avoided == avoided + 1
        assert edge.batcher.vmap_padded_lanes >= 1
        assert not any("#vmap3" in k for k in edge.cache.fingerprints)
        for r in results.values():
            np.testing.assert_allclose(
                np.asarray(r.outputs[0]), ref, rtol=1e-5, atol=1e-5
            )

    def test_padded_width_helper(self):
        from repro.serving.multitenant import _padded_width

        assert [_padded_width(n) for n in (1, 2, 3, 4, 5, 8, 9)] == [
            2, 2, 4, 4, 8, 8, 16,
        ]

    def test_padded_lanes_never_inflate_energy_or_occupancy(self):
        """A width-3 group executes through a padded width-4 vmap
        executable, but billing is by REAL lanes: per-client energy and the
        group's GPU occupancy are identical to the unpadded per-client loop
        of the same width."""
        model, x = make_mlp()

        def run(enable_vmap):
            edge = RRTOEdgeServer(execute=True)
            edge.batcher.enable_vmap = enable_vmap
            for _ in range(3):
                edge.connect(model)
            ids = list(edge.sessions)
            for _ in range(4):
                edge.run_round({c: (x,) for c in ids})
            assert all(
                s.client.mode == "replaying"
                for s in edge.sessions.values()
            )
            busy0 = edge.server.busy_seconds
            results = edge.run_round({c: (x,) for c in ids})
            return edge, results, edge.server.busy_seconds - busy0

        vmap_edge, vmap_res, vmap_busy = run(True)
        loop_edge, loop_res, loop_busy = run(False)
        assert vmap_edge.batcher.vmap_padded_lanes >= 1  # width 3 -> 4
        assert loop_edge.batcher.vmap_padded_lanes == 0
        # occupancy billed at the real width on both paths
        assert vmap_busy == pytest.approx(loop_busy, rel=1e-12)
        program = vmap_edge.server.context("c0").replay.program
        assert vmap_busy == pytest.approx(
            program.batched_compute_seconds(vmap_edge.server.device, 3),
            rel=1e-12,
        )
        # ...and per-client energy is identical: the masked lane exists only
        # inside the compiled executable, never in the accounting
        for cid in vmap_res:
            assert vmap_res[cid].joules == pytest.approx(
                loop_res[cid].joules, rel=1e-12
            )

    def test_aborted_vmap_batch_leaves_padding_stats_clean(self):
        """A group that bails out of the vmap path (a stateful member whose
        carried state is not seeded) falls back to the per-client loop: no
        padded lanes or avoided compiles may be recorded for the aborted
        batch — they would inflate the padding accounting for lanes that
        never executed."""

        def make_rnn():
            rng = np.random.default_rng(0)
            params = {"w": rng.normal(0, 0.1, (8, 8)).astype(np.float32)}

            def apply(p, x, state):
                new_state = jnp.tanh(state @ p["w"] + x)
                return [new_state.sum(axis=1), new_state]

            x = rng.normal(0, 1, (2, 8)).astype(np.float32)
            state0 = np.zeros((2, 8), np.float32)
            return OffloadableModel("rnn", apply, params, (x, state0)), x, state0

        model, x, state0 = make_rnn()
        edge = RRTOEdgeServer(execute=True)
        for _ in range(3):
            edge.connect(model)
        ids = list(edge.sessions)
        states = {c: state0 for c in ids}
        for _ in range(5):
            results = edge.run_round(
                {c: (x, states[c]) for c in ids}
            )
            for c in ids:
                states[c] = results[c].outputs[1]
        assert all(
            s.client.mode == "replaying" for s in edge.sessions.values()
        )
        padded0 = edge.batcher.vmap_padded_lanes
        avoided0 = edge.batcher.vmap_compiles_avoided
        batches0 = edge.batcher.vmap_batches
        # sabotage one member's seeded state: the vmap path must bail before
        # any padding accounting and fall back to the per-client loop
        saved = edge.server.context(ids[-1]).replay.carried_state
        edge.server.context(ids[-1]).replay.carried_state = None
        try:
            edge.batcher.begin_round(
                {
                    edge.sessions[ids[0]].client.replay_key: [
                        (
                            edge.sessions[c].client,
                            edge.sessions[c].replay_wire_inputs(
                                (x, states[c])
                            ),
                        )
                        for c in ids
                    ]
                }
            )
            group = edge.batcher._execute_group(
                edge.sessions[ids[0]].client.replay_key, edge.clock.t
            )
        finally:
            edge.server.context(ids[-1]).replay.carried_state = saved
        assert group is not None and group.outs is None  # loop fallback
        assert edge.batcher.vmap_batches == batches0
        assert edge.batcher.vmap_padded_lanes == padded0
        assert edge.batcher.vmap_compiles_avoided == avoided0


class TestDigestCache:
    def test_digest_cached_per_bound_replay(self):
        """The wire-input shape/dtype digest is computed once per binding and
        reused across rounds (the hot path under many co-tenants)."""
        model, x = make_mlp()
        edge = RRTOEdgeServer(execute=True)
        for _ in range(2):
            edge.connect(model)
        ids = list(edge.sessions)
        for _ in range(4):
            edge.run_round({c: (x,) for c in ids})
        assert all(
            s.client.mode == "replaying" for s in edge.sessions.values()
        )
        edge.run_round({c: (x,) for c in ids})       # digest computed once
        hits0 = edge.batcher.digest_cache_hits
        for _ in range(3):
            edge.run_round({c: (x,) for c in ids})
        assert edge.batcher.digest_cache_hits >= hits0 + 3

    def test_mismatched_submission_still_rejected(self):
        """The cached digest must not weaken the claim check: a submission
        whose values differ from the preload falls back to solo replay."""
        model, x = make_mlp()
        edge = RRTOEdgeServer(execute=True)
        sess = edge.connect(model)
        for _ in range(4):
            edge.run_round({"c0": (x,)})
        assert sess.client.mode == "replaying"
        cl = sess.client
        wire = sess.replay_wire_inputs((x,))
        edge.batcher.begin_round({cl.replay_key: [(cl, wire)]})
        wrong = [np.asarray(w) + 1.0 for w in wire]
        solo0 = edge.batcher.solo_replays
        outs, _ = edge.batcher.submit(cl, wrong, edge.clock.t)
        assert edge.batcher.solo_replays == solo0 + 1
        ref = np.asarray(
            jax.jit(model.apply)(model.params, np.asarray(wrong[0]))[0]
        )
        np.testing.assert_allclose(
            np.asarray(outs[0]), ref, rtol=1e-5, atol=1e-5
        )


class TestServerSegmentBatching:
    MBPS = 1e6 / 8.0

    def _locked_split_edge(self, n_clients=2, execute=True):
        """Co-tenant split sessions on one shared IOS, all replay-locked,
        with adaptive re-planning off so forced plans stay installed."""
        from repro.models.cnn_zoo import make_sensor_encoder
        from repro.partition import PartitionConfig

        model = make_sensor_encoder(scale=0.25, input_size=32, n_blocks=2)
        edge = RRTOEdgeServer(execute=execute)
        cfg = PartitionConfig(adaptive=False)
        sessions = []
        for _ in range(n_clients):
            s = edge.connect(model, min_repeats=2, partition=cfg)
            s.network.trace_bytes_per_s = np.full(16, 8.0 * self.MBPS)
            sessions.append(s)
        x = model.example_inputs
        for _ in range(6):
            edge.run_round({s.client_id: x for s in sessions})
        assert all(s.client.mode == "replaying" for s in sessions)
        return edge, sessions, model

    def test_same_server_segments_batch(self):
        """Split co-tenants whose plans share a server segment execute it as
        one batched GPU occupancy, and outputs stay exact."""
        from repro.partition import SegmentGraph, SplitPlan
        from repro.partition.segments import PLACE_DEVICE, PLACE_SERVER

        edge, sessions, model = self._locked_split_edge()
        n = SegmentGraph(sessions[0].client._ios_calls).n_ops
        plan = SplitPlan.from_placements(
            [PLACE_DEVICE] * 3 + [PLACE_SERVER] * (n - 3)
        )
        for s in sessions:
            s.client._install_plan(plan)
        x = model.example_inputs
        ref = None
        batches0 = edge.batcher.seg_batches
        results = edge.run_round({s.client_id: x for s in sessions})
        assert edge.batcher.seg_batches >= batches0 + 1
        assert edge.batcher.seg_batched >= 2
        for s in sessions:
            out = np.asarray(results[s.client_id].outputs[0])
            if ref is None:
                ref = out
            np.testing.assert_array_equal(out, ref)

    def test_different_device_cuts_still_share_server_segment(self):
        """The group key is (fingerprint, server-segment bounds), not the
        full plan: clients on *different* split plans of one shared IOS
        batch the server segment their plans have in common."""
        from repro.partition import SegmentGraph, SplitPlan
        from repro.partition.segments import PLACE_DEVICE, PLACE_SERVER

        edge, sessions, model = self._locked_split_edge()
        n = SegmentGraph(sessions[0].client._ios_calls).n_ops
        mid = max(5, n // 2)
        # plan A: device prefix, shared server segment, device tail, second
        # server segment; plan B: same prefix + shared segment, device tail
        plan_a = SplitPlan.from_placements(
            [PLACE_DEVICE] * 3
            + [PLACE_SERVER] * (mid - 3)
            + [PLACE_DEVICE] * 2
            + [PLACE_SERVER] * (n - mid - 2)
        )
        plan_b = SplitPlan.from_placements(
            [PLACE_DEVICE] * 3
            + [PLACE_SERVER] * (mid - 3)
            + [PLACE_DEVICE] * (n - mid)
        )
        assert plan_a.signature() != plan_b.signature()
        sessions[0].client._install_plan(plan_a)
        sessions[1].client._install_plan(plan_b)
        x = model.example_inputs
        batches0 = edge.batcher.seg_batches
        results = edge.run_round({s.client_id: x for s in sessions})
        # the shared (3, mid) segment batched; plan A's tail segment ran solo
        assert edge.batcher.seg_batches >= batches0 + 1
        assert edge.batcher.seg_solo >= 1
        a = np.asarray(results[sessions[0].client_id].outputs[0])
        b = np.asarray(results[sessions[1].client_id].outputs[0])
        np.testing.assert_array_equal(a, b)

    def test_full_server_clients_keep_whole_program_batching(self):
        """Split-segment batching must not siphon full-server replays out of
        the existing whole-program batch groups."""
        model, x = make_mlp()
        edge = RRTOEdgeServer(execute=True)
        for _ in range(2):
            edge.connect(model)
        ids = list(edge.sessions)
        for _ in range(4):
            edge.run_round({c: (x,) for c in ids})
        batches0 = edge.batcher.batches_executed
        edge.run_round({c: (x,) for c in ids})
        assert edge.batcher.batches_executed == batches0 + 1
        assert edge.batcher.seg_batches == 0


class TestLRUEviction:
    def test_evicts_least_recently_used(self):
        class P:  # stand-in program
            pass

        cache = ReplayCache(capacity=2)
        pa, pb, pc = P(), P(), P()
        cache.put("a", pa)
        cache.put("b", pb)
        assert cache.get("a") is pa  # touch a -> b becomes LRU
        cache.put("c", pc)
        assert "b" not in cache and "a" in cache and "c" in cache
        assert cache.stats.evictions == 1

    def test_refetch_after_eviction_recompiles(self):
        """Evicting a fingerprint forces a rebuild on the next miss."""
        model_a, xa = make_mlp()
        model_b, xb = make_deep_mlp()
        edge = RRTOEdgeServer(execute=True)
        edge.cache.capacity = 1
        edge.connect(model_a)           # c0 locks model A -> cached
        for _ in range(3):
            edge.run_round({"c0": (xa,)})
        edge.connect(model_b)           # c1 locks model B -> evicts A
        for _ in range(3):
            edge.run_round({"c0": (xa,), "c1": (xb,)})
        assert edge.compile_count == 2
        assert edge.cache.stats.evictions == 1
        # a third client on model A misses the (evicted) entry and recompiles
        edge.connect(model_a)
        for _ in range(3):
            edge.run_round({"c0": (xa,), "c1": (xb,), "c2": (xa,)})
        assert edge.sessions["c2"].client.mode == "replaying"
        assert edge.compile_count == 3


class TestCachePersistence:
    def test_save_load_roundtrip_metadata(self, tmp_path):
        model, x = make_mlp()
        edge = RRTOEdgeServer(execute=True)
        edge.connect(model)
        for _ in range(3):
            edge.run_round({"c0": (x,)})
        path = str(tmp_path / "replay_cache.json")
        assert edge.save_cache(path) == 1
        fp = edge.cache.fingerprints[0]

        fresh = ReplayCache()
        assert fresh.load(path) == 1
        assert fp in fresh                      # membership: IOS validated
        assert fresh.get(fp) is None            # but no compiled program yet
        meta = fresh.known_metadata(fp)
        assert meta["n_kernels"] > 0 and meta["total_flops"] > 0

    def test_restarted_server_skips_revalidation(self, tmp_path):
        """A client joining the restarted server adopts the persisted IOS
        after ONE recorded inference; the executable recompiles once."""
        model, x = make_mlp()
        warm = RRTOEdgeServer(execute=True)
        warm.connect(model)
        for _ in range(3):
            warm.run_round({"c0": (x,)})
        path = str(tmp_path / "cache.json")
        warm.save_cache(path)

        cold = RRTOEdgeServer(execute=True)      # simulated restart
        cold.load_cache(path)
        sess = cold.connect(model)
        cold.run_round({"c0": (x,)})
        assert sess.client.mode == "replaying"
        assert sess.client.cache_adopted
        rec = [r for r in sess.history if r.mode == "recording"]
        assert len(rec) == 1
        res = cold.run_round({"c0": (x,)})["c0"]
        ref = np.asarray(jax.jit(model.apply)(model.params, x)[0])
        np.testing.assert_allclose(
            np.asarray(res.outputs[0]), ref, rtol=1e-5, atol=1e-5
        )
        assert cold.compile_count == 1

    def test_version_mismatch_rejected(self, tmp_path):
        import json

        path = tmp_path / "bad.json"
        path.write_text(json.dumps({"version": 99, "fingerprints": {}}))
        with pytest.raises(ValueError, match="version"):
            ReplayCache().load(str(path))


class TestSingleClientEquivalence:
    def test_edge_single_client_matches_plain_session(self):
        """One client through the multi-tenant stack behaves like the plain
        single-tenant OffloadSession: same outputs, same mode trajectory,
        same per-inference RPC counts."""
        model, x = make_mlp()
        plain = OffloadSession(
            model, "rrto", network=indoor_network(0), min_repeats=3
        )
        plain.load()
        plain_hist = [plain.infer(x) for _ in range(6)]

        edge = RRTOEdgeServer(execute=True)
        sess = edge.connect(model, seed=0)
        edge_hist = [edge.run_round({"c0": (x,)})["c0"] for _ in range(6)]

        for p, e in zip(plain_hist, edge_hist):
            assert p.mode == e.mode
            assert p.rpcs == e.rpcs
            np.testing.assert_allclose(
                np.asarray(p.outputs[0]),
                np.asarray(e.outputs[0]),
                rtol=1e-6,
                atol=1e-6,
            )

    def test_ingress_contention_slows_transfers(self):
        ing = ServerIngress(capacity_bytes_per_s=10e6)
        net = indoor_network(0)
        net.ingress = ing
        ing.active_clients = 1
        t1 = net.transfer_time(1e6, 0.0)
        ing.active_clients = 10
        t10 = net.transfer_time(1e6, 0.0)
        assert t10 > t1 * 5  # fair share: 10 MB/s -> 1 MB/s per client


class TestDeviationDuringFormedRound:
    """A DAM deviation (``_fallback``) firing while the batcher already
    holds the client's preload in a formed round: the deviating client must
    exit the round cleanly (revert to recording, produce a correct result)
    and its co-tenants' batched replays must stay bitwise-identical to an
    edge that never saw the deviation."""

    CIDS = ("c0", "c1", "c2")

    def _build(self):
        edge = RRTOEdgeServer(execute=True)
        model, x = make_mlp()
        for cid in self.CIDS:
            edge.connect(model, client_id=cid, min_repeats=2)
        for _ in range(4):
            edge.run_round({cid: (x,) for cid in self.CIDS})
        for cid in self.CIDS:
            assert edge.sessions[cid].client.mode == "replaying"
        keys = {edge.sessions[cid].client.replay_key for cid in self.CIDS}
        assert len(keys) == 1       # one shared batched-replay group
        return edge, x

    def test_deviant_exits_round_cleanly_cotenants_bitwise(self):
        from repro.core.flatten import flatten_closed_jaxpr

        edge, x = self._build()
        control, x_ctl = self._build()
        want = control.run_round({cid: (x_ctl,) for cid in self.CIDS})

        # form the round exactly as run_round does: all three replaying
        # clients preloaded under their shared fingerprint
        entries = {}
        for cid in self.CIDS:
            sess = edge.sessions[cid]
            entries.setdefault(sess.client.replay_key, []).append(
                (sess.client, sess.replay_wire_inputs((x,)))
            )
        edge.batcher.begin_round(entries, {})

        # co-tenants claim their batch lanes first
        res = {cid: edge.sessions[cid].infer(x) for cid in ("c0", "c1")}

        # ... then c2 — still preloaded in the formed round — runs a
        # different op stream through its own interceptor: relu where the
        # locked IOS recorded tanh@w2.  The DAM must fall back mid-round.
        sess2 = edge.sessions["c2"]
        rng = np.random.default_rng(0)
        w1 = rng.normal(0, 0.1, (16, 32)).astype(np.float32)
        jb = flatten_closed_jaxpr(
            jax.make_jaxpr(lambda xx: [jax.nn.relu(xx @ w1)])(x)
        )
        addrs_b = sess2.interceptor.upload_params(
            [np.asarray(c) for c in jb.consts]
        )
        out2 = sess2.interceptor.run(jb, addrs_b, [x])
        edge.batcher.end_round()

        deviant = sess2.client
        assert deviant.fallbacks >= 1
        assert deviant.mode == "recording"
        assert np.asarray(out2[0]).shape == (2, 32)    # the relu stream ran

        # co-tenants' batched replays: bitwise-equal to the clean twin
        for cid in ("c0", "c1"):
            assert np.array_equal(
                np.asarray(res[cid].outputs[0]),
                np.asarray(want[cid].outputs[0]),
            )
        # exactly one unclaimed lane remains — the deviant's preloaded
        # batch slot, abandoned when the DAM fell back; the next round's
        # formation sweeps it, so the no-show never leaks across rounds
        assert edge.batcher.pending_depth == 1

        # the edge still serves the deviant: it re-records through normal
        # rounds and re-locks into batched replay alongside its co-tenants
        for _ in range(4):
            edge.run_round({cid: (x,) for cid in self.CIDS})
        assert edge.batcher.pending_depth == 0
        assert edge.sessions["c2"].client.mode == "replaying"
        final = edge.run_round({cid: (x,) for cid in self.CIDS})
        ctl_final = control.run_round({cid: (x_ctl,) for cid in self.CIDS})
        for cid in self.CIDS:
            assert np.array_equal(
                np.asarray(final[cid].outputs[0]),
                np.asarray(ctl_final[cid].outputs[0]),
            )
