"""Multi-tenant replay-cache serving: fingerprint stability across clients,
cache-hit adoption skipping the recording phase, LRU eviction, cross-client
batched replay correctness, per-client state isolation, and single-client
equivalence with the pre-refactor path."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.netsim import ServerIngress, indoor_network
from repro.core.offload import OffloadableModel, OffloadSession
from repro.core.opseq import ios_fingerprint
from repro.serving.multitenant import RRTOEdgeServer
from repro.serving.replay_cache import ReplayCache


def make_mlp(seed=0, d_in=16, d_hidden=32, d_out=8):
    rng = np.random.default_rng(seed)
    params = {
        "w1": rng.normal(0, 0.1, (d_in, d_hidden)).astype(np.float32),
        "w2": rng.normal(0, 0.1, (d_hidden, d_out)).astype(np.float32),
    }

    def apply(p, x):
        return [jnp.tanh(x @ p["w1"]) @ p["w2"]]

    x = rng.normal(0, 1, (2, d_in)).astype(np.float32)
    return OffloadableModel(f"mlp{seed}", apply, params, (x,)), x


def make_deep_mlp(seed=0, d=16):
    rng = np.random.default_rng(seed)
    params = {
        "w1": rng.normal(0, 0.1, (d, d)).astype(np.float32),
        "w2": rng.normal(0, 0.1, (d, d)).astype(np.float32),
        "w3": rng.normal(0, 0.1, (d, d)).astype(np.float32),
    }

    def apply(p, x):
        h = jnp.tanh(x @ p["w1"])
        h = jax.nn.relu(h @ p["w2"])
        return [h @ p["w3"]]

    x = rng.normal(0, 1, (2, d)).astype(np.float32)
    return OffloadableModel(f"deep{seed}", apply, params, (x,)), x


class TestFingerprint:
    def test_stable_across_clients(self):
        """Two independent sessions (own interceptor, own allocator) running
        the same model must produce the same IOS fingerprint."""
        ios = []
        for seed in (0, 1):  # different network seeds, same model structure
            model, x = make_mlp()
            sess = OffloadSession(
                model, "rrto", min_repeats=3, seed=seed, execute=False
            )
            sess.load()
            for _ in range(5):
                sess.infer(x)
            assert sess.client.ios is not None
            ios.append(sess.client.ios)
        assert ios_fingerprint(ios[0].records) == ios_fingerprint(ios[1].records)

    def test_differs_across_models(self):
        fps = []
        for maker in (make_mlp, make_deep_mlp):
            model, x = maker()
            sess = OffloadSession(model, "rrto", min_repeats=3, execute=False)
            sess.load()
            for _ in range(5):
                sess.infer(x)
            fps.append(ios_fingerprint(sess.client.ios.records))
        assert fps[0] != fps[1]

    def test_param_values_do_not_matter(self):
        """Same architecture, different weights -> same fingerprint (the
        structure, not the data, is the content address)."""
        fps = []
        for seed in (0, 7):
            model, x = make_mlp(seed=seed)
            sess = OffloadSession(model, "rrto", min_repeats=3, execute=False)
            sess.load()
            for _ in range(5):
                sess.infer(x)
            fps.append(ios_fingerprint(sess.client.ios.records))
        assert fps[0] == fps[1]


class TestCacheAdoption:
    def test_late_client_skips_recording(self):
        """A client joining after the cache is warm adopts the IOS after a
        single recorded inference instead of min_repeats of them."""
        model, x = make_mlp()
        edge = RRTOEdgeServer(execute=True)
        first = edge.connect(model, min_repeats=3)
        for _ in range(3):
            edge.run_round({"c0": (x,)})
        assert first.client.mode == "replaying"
        assert not first.client.cache_adopted

        late = edge.connect(model, min_repeats=3)
        edge.run_round({"c0": (x,), "c1": (x,)})
        assert late.client.mode == "replaying"
        assert late.client.cache_adopted
        rec = [r for r in late.history if r.mode == "recording"]
        assert len(rec) == 1  # one recorded inference, not three

    def test_compile_exactly_once(self):
        model, x = make_mlp()
        edge = RRTOEdgeServer(execute=True)
        edge.connect(model)
        for _ in range(3):
            edge.run_round({"c0": (x,)})
        for i in range(3):
            edge.connect(model)
            edge.run_round({f"c{j}": (x,) for j in range(i + 2)})
        assert edge.compile_count == 1
        assert edge.cache.stats.hits == 3  # one bind per adopting client

    def test_batched_replay_outputs_correct(self):
        model, x = make_mlp()
        ref = np.asarray(jax.jit(model.apply)(model.params, x)[0])
        edge = RRTOEdgeServer(execute=True)
        for i in range(3):
            edge.connect(model)
        all_ids = list(edge.sessions)
        for _ in range(4):
            results = edge.run_round({c: (x,) for c in all_ids})
        assert all(
            s.client.mode == "replaying" for s in edge.sessions.values()
        )
        for r in results.values():
            np.testing.assert_allclose(
                np.asarray(r.outputs[0]), ref, rtol=1e-5, atol=1e-5
            )
        assert edge.batcher.batches_executed >= 1
        assert max(edge.batcher.batch_sizes) == 3

    def test_vmap_batch_bitwise_equals_loop(self):
        """Shared-param co-tenants execute as one true vmap-batched call;
        the outputs must be bitwise identical to the per-client loop."""
        model, _ = make_mlp()
        rng = np.random.default_rng(5)
        per_client = {f"c{i}": rng.normal(0, 1, (2, 16)).astype(np.float32)
                      for i in range(3)}

        def run(enable_vmap):
            edge = RRTOEdgeServer(execute=True)
            edge.batcher.enable_vmap = enable_vmap
            for _ in range(3):
                edge.connect(model)
            for _ in range(5):
                results = edge.run_round(
                    {c: (x,) for c, x in per_client.items()}
                )
            return results, edge

        vmapped, edge_v = run(True)
        looped, edge_l = run(False)
        assert edge_v.batcher.vmap_batches >= 1
        assert edge_l.batcher.vmap_batches == 0
        for c in per_client:
            np.testing.assert_array_equal(
                np.asarray(vmapped[c].outputs[0]),
                np.asarray(looped[c].outputs[0]),
            )

    def test_vmap_disabled_falls_back_to_loop(self):
        model, x = make_mlp()
        edge = RRTOEdgeServer(execute=True)
        edge.batcher.enable_vmap = False
        for _ in range(3):
            edge.connect(model)
        ids = list(edge.sessions)
        for _ in range(5):
            results = edge.run_round({c: (x,) for c in ids})
        assert edge.batcher.vmap_batches == 0
        assert edge.batcher.batches_executed >= 1
        ref = np.asarray(jax.jit(model.apply)(model.params, x)[0])
        for r in results.values():
            np.testing.assert_allclose(
                np.asarray(r.outputs[0]), ref, rtol=1e-5, atol=1e-5
            )

    def test_per_client_params_isolated(self):
        """Clients with the same architecture but different weights share one
        compiled program yet keep their own parameter memory."""
        m0, x = make_mlp(seed=0)
        m1, _ = make_mlp(seed=7)
        edge = RRTOEdgeServer(execute=True)
        edge.connect(m0)
        edge.connect(m1)
        for _ in range(4):
            results = edge.run_round({"c0": (x,), "c1": (x,)})
        assert edge.compile_count == 1  # same fingerprint, one program
        for model, cid in ((m0, "c0"), (m1, "c1")):
            ref = np.asarray(jax.jit(model.apply)(model.params, x)[0])
            np.testing.assert_allclose(
                np.asarray(results[cid].outputs[0]), ref, rtol=1e-5, atol=1e-5
            )


class TestLRUEviction:
    def test_evicts_least_recently_used(self):
        class P:  # stand-in program
            pass

        cache = ReplayCache(capacity=2)
        pa, pb, pc = P(), P(), P()
        cache.put("a", pa)
        cache.put("b", pb)
        assert cache.get("a") is pa  # touch a -> b becomes LRU
        cache.put("c", pc)
        assert "b" not in cache and "a" in cache and "c" in cache
        assert cache.stats.evictions == 1

    def test_refetch_after_eviction_recompiles(self):
        """Evicting a fingerprint forces a rebuild on the next miss."""
        model_a, xa = make_mlp()
        model_b, xb = make_deep_mlp()
        edge = RRTOEdgeServer(execute=True)
        edge.cache.capacity = 1
        edge.connect(model_a)           # c0 locks model A -> cached
        for _ in range(3):
            edge.run_round({"c0": (xa,)})
        edge.connect(model_b)           # c1 locks model B -> evicts A
        for _ in range(3):
            edge.run_round({"c0": (xa,), "c1": (xb,)})
        assert edge.compile_count == 2
        assert edge.cache.stats.evictions == 1
        # a third client on model A misses the (evicted) entry and recompiles
        edge.connect(model_a)
        for _ in range(3):
            edge.run_round({"c0": (xa,), "c1": (xb,), "c2": (xa,)})
        assert edge.sessions["c2"].client.mode == "replaying"
        assert edge.compile_count == 3


class TestCachePersistence:
    def test_save_load_roundtrip_metadata(self, tmp_path):
        model, x = make_mlp()
        edge = RRTOEdgeServer(execute=True)
        edge.connect(model)
        for _ in range(3):
            edge.run_round({"c0": (x,)})
        path = str(tmp_path / "replay_cache.json")
        assert edge.save_cache(path) == 1
        fp = edge.cache.fingerprints[0]

        fresh = ReplayCache()
        assert fresh.load(path) == 1
        assert fp in fresh                      # membership: IOS validated
        assert fresh.get(fp) is None            # but no compiled program yet
        meta = fresh.known_metadata(fp)
        assert meta["n_kernels"] > 0 and meta["total_flops"] > 0

    def test_restarted_server_skips_revalidation(self, tmp_path):
        """A client joining the restarted server adopts the persisted IOS
        after ONE recorded inference; the executable recompiles once."""
        model, x = make_mlp()
        warm = RRTOEdgeServer(execute=True)
        warm.connect(model)
        for _ in range(3):
            warm.run_round({"c0": (x,)})
        path = str(tmp_path / "cache.json")
        warm.save_cache(path)

        cold = RRTOEdgeServer(execute=True)      # simulated restart
        cold.load_cache(path)
        sess = cold.connect(model)
        cold.run_round({"c0": (x,)})
        assert sess.client.mode == "replaying"
        assert sess.client.cache_adopted
        rec = [r for r in sess.history if r.mode == "recording"]
        assert len(rec) == 1
        res = cold.run_round({"c0": (x,)})["c0"]
        ref = np.asarray(jax.jit(model.apply)(model.params, x)[0])
        np.testing.assert_allclose(
            np.asarray(res.outputs[0]), ref, rtol=1e-5, atol=1e-5
        )
        assert cold.compile_count == 1

    def test_version_mismatch_rejected(self, tmp_path):
        import json

        path = tmp_path / "bad.json"
        path.write_text(json.dumps({"version": 99, "fingerprints": {}}))
        with pytest.raises(ValueError, match="version"):
            ReplayCache().load(str(path))


class TestSingleClientEquivalence:
    def test_edge_single_client_matches_plain_session(self):
        """One client through the multi-tenant stack behaves like the plain
        single-tenant OffloadSession: same outputs, same mode trajectory,
        same per-inference RPC counts."""
        model, x = make_mlp()
        plain = OffloadSession(
            model, "rrto", network=indoor_network(0), min_repeats=3
        )
        plain.load()
        plain_hist = [plain.infer(x) for _ in range(6)]

        edge = RRTOEdgeServer(execute=True)
        sess = edge.connect(model, seed=0)
        edge_hist = [edge.run_round({"c0": (x,)})["c0"] for _ in range(6)]

        for p, e in zip(plain_hist, edge_hist):
            assert p.mode == e.mode
            assert p.rpcs == e.rpcs
            np.testing.assert_allclose(
                np.asarray(p.outputs[0]),
                np.asarray(e.outputs[0]),
                rtol=1e-6,
                atol=1e-6,
            )

    def test_ingress_contention_slows_transfers(self):
        ing = ServerIngress(capacity_bytes_per_s=10e6)
        net = indoor_network(0)
        net.ingress = ing
        ing.active_clients = 1
        t1 = net.transfer_time(1e6, 0.0)
        ing.active_clients = 10
        t10 = net.transfer_time(1e6, 0.0)
        assert t10 > t1 * 5  # fair share: 10 MB/s -> 1 MB/s per client
