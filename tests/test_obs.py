"""Unified tracing + metrics layer (repro.obs).

Load-bearing properties, in order:

* a *disabled* tracer is provably free — the same fleet workload with and
  without tracing produces bitwise-identical outputs and identical legacy
  counters, and an unattached tracer records zero events;
* spans nest (begin/end parent links) and per-track timestamps are monotone
  on the shared :class:`~repro.core.engine.SimClock`;
* hedged dispatch emits a primary *and* a backup ``hedge_dispatch`` span
  and the race loser is annotated ``cancelled=True`` after resolution;
* the Chrome trace-event export is schema-valid and carries the
  record/replay/hedge/migration spans across >= 2 replica tracks;
* one root ``MetricsRegistry.snapshot()`` agrees with every legacy stats
  surface (client RPCs, cache hits, hedge counts, migrations).
"""
import json
import re

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.offload import OffloadableModel, OffloadSession
from repro.obs import (
    MetricsRegistry,
    RegistryBackedStats,
    Tracer,
    percentile,
    to_chrome_trace,
    write_chrome_trace,
)
from repro.partition.planner import plan_cost, plan_partition
from repro.partition.segments import SegmentGraph
from repro.serving import EdgeFleet

MBPS = 1e6 / 8.0


def make_mlp(seed=0, d_in=16, d_hidden=32, d_out=8):
    rng = np.random.default_rng(seed)
    params = {
        "w1": jnp.asarray(rng.normal(size=(d_in, d_hidden)), jnp.float32),
        "w2": jnp.asarray(rng.normal(size=(d_hidden, d_out)), jnp.float32),
    }

    def apply(p, x):
        return [jnp.tanh(x @ p["w1"]) @ p["w2"]]

    x = jnp.asarray(rng.normal(size=(1, d_in)), jnp.float32)
    return OffloadableModel(f"mlp{seed}", apply, params, (x,)), np.asarray(x)


# ---------------------------------------------------------------------------
# metrics primitives
# ---------------------------------------------------------------------------
class TestMetrics:
    def test_counter_gauge_histogram(self):
        reg = MetricsRegistry()
        reg.counter("n").value += 3
        assert reg.counter("n").value == 3
        reg.gauge("depth").set(2.5)
        h = reg.histogram("lat")
        for v in (1.0, 2.0, 3.0, 4.0):
            h.observe(v)
        assert h.count == 4 and h.mean == pytest.approx(2.5)
        assert h.p50 <= h.p95 <= h.p99 <= 4.0
        s = h.summary()
        assert set(s) == {"count", "mean", "p50", "p95", "p99"}

    def test_percentile_nearest_rank(self):
        xs = list(range(1, 101))
        assert percentile(xs, 0) == 1
        assert percentile(xs, 100) == 100
        assert percentile(xs, 99) == 99
        assert percentile([], 50) == 0.0

    def test_scope_shares_one_store(self):
        root = MetricsRegistry()
        root.scope("r0").scope("cache").counter("hits").value += 2
        root.scope("r1").scope("cache").counter("hits").value += 5
        snap = root.snapshot()
        assert snap["r0.cache.hits"] == 2
        assert snap["r1.cache.hits"] == 5
        # a scoped snapshot sees only its subtree, unprefixed
        assert root.scope("r1").snapshot() == {"cache.hits": 5}

    def test_registry_backed_stats_proxy(self):
        class S(RegistryBackedStats):
            _fields = (("n", 0), ("bytes", 0.0))

        s = S()
        s.n += 2
        s.bytes += 0.5
        assert s.n == 2 and s.bytes == 0.5
        assert s.as_dict() == {"n": 2, "bytes": 0.5}
        # numbers live in the handed-in registry scope, not the instance
        root = MetricsRegistry()
        s2 = S(registry=root.scope("x"))
        s2.n += 7
        assert root.snapshot()["x.n"] == 7
        with pytest.raises(AttributeError):
            s2.nonexistent_field


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------
class TestTracer:
    def test_spans_nest(self):
        t = Tracer()
        outer = t.begin("x", "outer", 0.0)
        inner = t.begin("x", "inner", 1.0)
        t.end(inner, 2.0)
        t.end(outer, 3.0)
        assert t.spans[outer].parent is None
        assert t.spans[inner].parent == outer
        assert t.spans[inner].dur == pytest.approx(1.0)
        # tracks nest independently
        other = t.begin("y", "solo", 0.5)
        assert t.spans[other].parent is None

    def test_end_pops_unclosed_children(self):
        t = Tracer()
        outer = t.begin("x", "outer", 0.0)
        t.begin("x", "dangling", 1.0)
        t.end(outer, 2.0)   # pops the dangling child too
        fresh = t.begin("x", "fresh", 3.0)
        assert t.spans[fresh].parent is None

    def test_complete_span_parents_without_pushing(self):
        t = Tracer()
        outer = t.begin("x", "outer", 0.0)
        leaf = t.span("x", "leaf", 0.5, 1.0)
        assert t.spans[leaf].parent == outer
        # the complete span is not on the stack: the next leaf still
        # parents under `outer`, not under `leaf`
        leaf2 = t.span("x", "leaf2", 1.0, 1.5)
        assert t.spans[leaf2].parent == outer

    def test_annotate_patches_args(self):
        t = Tracer()
        sid = t.span("x", "race", 0.0, 1.0, role="primary")
        t.annotate(sid, winner=False, cancelled=True)
        assert t.spans[sid].args == {
            "role": "primary", "winner": False, "cancelled": True
        }


# ---------------------------------------------------------------------------
# a fully traced fleet run: straggler -> hedge, plus one live migration
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def traced_fleet():
    tracer = Tracer()
    fleet = EdgeFleet(2, hedging=True, min_observations=4, tracer=tracer)
    model, x = make_mlp(0)
    c = fleet.connect(model, client_id="u0", min_repeats=2)
    for _ in range(8):
        c.infer(x)
    assert c.session.client.mode == "replaying"
    # stall the primary hard on every request: the adaptive deadline trips
    # and the router hedges to the second replica
    prim = fleet.replica(c.primary)
    prim.slowdown = lambda i: 1.0
    for _ in range(6):
        c.infer(x)
    prim.slowdown = lambda i: 0.0
    assert fleet.router.stats.hedged > 0
    # a second client, migrated live between replicas; speculation is
    # suspended for this phase so its recording rounds (slow vs. the
    # replay-built deadline) don't fork a backup onto the migration target
    fleet.router.hedge_multiplier = float("inf")
    model2, x2 = make_mlp(1)
    c2 = fleet.connect(model2, client_id="u1", min_repeats=2)
    for _ in range(4):
        c2.infer(x2)
    fleet.migrate("u1")
    c2.infer(x2)
    return tracer, fleet, c


class TestTracedFleet:
    def test_hedge_primary_and_backup_spans_loser_cancelled(
        self, traced_fleet
    ):
        tracer, _fleet, _c = traced_fleet
        by_req = {}
        for sp in tracer.find("hedge_dispatch"):
            key = (sp.args["client"], sp.args["req"])
            by_req.setdefault(key, []).append(sp)
        raced = [sps for sps in by_req.values() if len(sps) >= 2]
        assert raced, "no request ever raced primary vs backup"
        for sps in raced:
            roles = {sp.args["role"] for sp in sps}
            assert roles == {"primary", "backup"}
            winners = [sp for sp in sps if sp.args["winner"]]
            assert len(winners) == 1
            for sp in sps:
                assert sp.args["cancelled"] == (not sp.args["winner"])

    def test_timestamps_monotone_per_track(self, traced_fleet):
        tracer, _fleet, _c = traced_fleet
        assert all(sp.t1 is None or sp.t1 >= sp.t0 for sp in tracer.spans)
        last = {}
        for sp in tracer.spans:
            assert sp.t0 >= last.get(sp.track, 0.0), (
                f"track {sp.track} went backwards at {sp.name}"
            )
            last[sp.track] = sp.t0
        for ins in tracer.instants:
            assert ins.t >= 0.0

    def test_chrome_trace_schema(self, traced_fleet, tmp_path):
        tracer, _fleet, _c = traced_fleet
        doc = json.loads(json.dumps(to_chrome_trace(tracer), default=str))
        assert doc["displayTimeUnit"] == "ms"
        events = doc["traceEvents"]
        assert events
        names = set()
        tracks = set()
        for e in events:
            assert e["ph"] in {"X", "i", "C", "M"}
            if e["ph"] == "M":
                assert e["name"] in {"process_name", "thread_name"}
                continue
            assert isinstance(e["ts"], (int, float))
            assert e["pid"] == e["tid"].split("/", 1)[0]
            names.add(e["name"])
            tracks.add(e["tid"])
            if e["ph"] == "X":
                assert e["dur"] >= 0.0
            if e["ph"] == "i":
                assert e["s"] == "t"
        assert {"record_rpc", "replay_call", "hedge_dispatch",
                "migrate"} <= names
        replica_tracks = {t for t in tracks if re.match(r"^r\d+/", t)}
        assert len({t.split("/", 1)[0] for t in replica_tracks}) >= 2
        # file round-trip
        path = tmp_path / "trace.json"
        write_chrome_trace(tracer, str(path))
        assert json.loads(path.read_text())["traceEvents"]

    def test_root_snapshot_agrees_with_legacy_counters(self, traced_fleet):
        _tracer, fleet, c = traced_fleet
        snap = fleet.metrics.snapshot()
        assert snap["fleet.migrations"] == fleet.stats.migrations == 1
        assert snap["fleet.placements"] == fleet.stats.placements
        assert snap["hedge.requests"] == fleet.router.stats.requests
        assert snap["hedge.hedged"] == fleet.router.stats.hedged > 0
        assert (
            snap["hedge.latency_s"]["count"]
            == len(fleet.router.stats.latencies)
        )
        for i, rep in enumerate(fleet.replicas):
            assert snap[f"r{i}.cache.hits"] == rep.edge.cache.stats.hits
            assert (
                snap[f"r{i}.batcher.batches_executed"]
                == rep.edge.batcher.stats.batches_executed
            )
        # u0 never migrated: each of its sessions reports under the scope
        # of the replica that owns it, and RPC/byte counts agree
        for name, sess in c.sessions.items():
            assert (
                snap[f"{name}.client.u0.rpcs"] == sess.client.stats.rpcs > 0
            )
            assert (
                snap[f"{name}.client.u0.network_bytes"]
                == sess.client.stats.network_bytes
            )


# ---------------------------------------------------------------------------
# disabled tracing is provably free
# ---------------------------------------------------------------------------
class TestDisabledTracer:
    @staticmethod
    def _run(tracer):
        fleet = EdgeFleet(2, min_observations=4, tracer=tracer)
        model, x = make_mlp(7)
        c = fleet.connect(model, client_id="u0", min_repeats=2)
        outs = [np.asarray(c.infer(x).outputs[0]) for _ in range(6)]
        return outs, c.session.client.stats.as_dict(), fleet.summary()

    def test_disabled_is_bitwise_identical_and_silent(self):
        idle = Tracer()               # constructed but never attached
        base_outs, base_stats, base_sum = self._run(None)
        assert idle.n_events == 0     # tracing off => zero events
        tr = Tracer()
        t_outs, t_stats, t_sum = self._run(tr)
        assert tr.n_events > 0
        for a, b in zip(base_outs, t_outs):
            assert np.array_equal(a, b)
        assert base_stats == t_stats
        assert base_sum["fleet"] == t_sum["fleet"]
        assert base_sum["router"] == t_sum["router"]
        assert base_sum["backhaul_bytes"] == t_sum["backhaul_bytes"]


# ---------------------------------------------------------------------------
# planner explain report
# ---------------------------------------------------------------------------
class TestPlanExplain:
    def test_plan_explain_event_matches_choice(self):
        model, x = make_mlp(3)
        sess = OffloadSession(model, "rrto", min_repeats=2)
        sess.load()
        for _ in range(4):
            sess.infer(x)
        graph = SegmentGraph(sess.client._ios_calls)
        tracer = Tracer()
        best = plan_partition(
            graph, sess.client_device, sess.server_device, 16 * MBPS,
            tracer=tracer, trace_track="planner", now=1.5,
        )
        explains = [
            i for i in tracer.instants if i.name == "plan_explain"
        ]
        assert len(explains) == 1
        ev = explains[0]
        assert ev.track == "planner" and ev.t == 1.5
        rows = ev.args["candidates"]
        assert len(rows) >= 2          # at least both binary endpoints
        assert ev.args["chosen"] == best.plan.signature()
        by_cost = min(rows, key=lambda r: r["cost"])
        assert by_cost["plan"] == best.plan.signature()
        assert by_cost["cost"] == pytest.approx(
            plan_cost(best, "latency")
        )
