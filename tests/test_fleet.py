"""Fleet-scale replicated serving: hedged dispatch, cache replication, and
carried-state migration — plus the fault-injection layer that hardens them.

The load-bearing property is at the top: a stateful streaming session
migrated between replicas mid-decode is *bitwise-identical* (emitted tokens
AND the donated carried state) to the same session never migrating, across
multiple registry model families and randomized migration points.
"""
import os

import numpy as np
import jax.numpy as jnp
import pytest

from repro.configs.base import ArchConfig
from repro.core.netsim import multi_node_ingress
from repro.core.offload import OffloadableModel
from repro.distributed.straggler import (
    OBSERVATION_WINDOW,
    AllReplicasFailedError,
    HedgedRouter,
    NoHealthyReplicaError,
    ReplicaModel,
)
from repro.serving import EdgeFleet, FleetClient, ReplayCache, RRTOServedLM

DENSE = ArchConfig(
    name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_head=16, d_ff=128, vocab=256, dtype="float32",
    rope_theta=1e4,
)
# second registry family: sLSTM/mLSTM hybrid — a different carried-state
# layout (recurrent cell state, not a KV ring) through the same migration
XLSTM = ArchConfig(
    name="x", family="ssm", n_layers=2, d_model=32, n_heads=2,
    n_kv_heads=2, d_head=16, d_ff=0, vocab=128, dtype="float32",
    ssm_chunk=16, slstm_every=2, slstm_ff=48,
)
PROMPT = np.array([[3, 7, 11, 13]], np.int32)


def make_mlp(seed=0, d_in=16, d_hidden=32, d_out=8):
    rng = np.random.default_rng(seed)
    params = {
        "w1": jnp.asarray(rng.normal(size=(d_in, d_hidden)), jnp.float32),
        "w2": jnp.asarray(rng.normal(size=(d_hidden, d_out)), jnp.float32),
    }

    def apply(p, x):
        return [jnp.tanh(x @ p["w1"]) @ p["w2"]]

    x = jnp.asarray(rng.normal(size=(1, d_in)), jnp.float32)
    return OffloadableModel(f"mlp{seed}", apply, params, (x,)), np.asarray(x)


def decode_stream(cfg, migrate_at=None, max_new=8):
    """Run one stateful decode stream on a 2-replica fleet, optionally
    migrating the session r0 -> r1 before step ``migrate_at``; returns
    (tokens, final carried state, fleet)."""
    fleet = EdgeFleet(2, min_observations=4)
    lm = RRTOServedLM(
        cfg, edge=fleet.replicas[0].edge, client_id="u0", seed=0,
        min_repeats=2,
    )
    g = lm.start_generation(PROMPT, max_new_tokens=max_new)
    for step in range(lm.steps_total(g)):
        if migrate_at is not None and step == migrate_at:
            assert fleet.migrate("u0", "r1") == "r1"
        outs = lm.session.infer(*lm.step_inputs(g)).outputs
        lm.absorb_step(g, outs)
    tokens = np.concatenate(g["out"], axis=1)
    state = fleet.locate("u0").edge.server.export_carried_state("u0")
    return tokens, state, fleet


class TestMigrationEquivalence:
    """Property: mid-stream migration is invisible to the decode."""

    @pytest.mark.parametrize("cfg", [DENSE, XLSTM], ids=lambda c: c.family)
    def test_migrated_stream_bitwise_identical(self, cfg, rng):
        base_tokens, base_state, _ = decode_stream(cfg)
        assert base_state is not None, "stream never turned stateful"
        n_steps = PROMPT.shape[1] + 8 - 1
        # randomized migration points covering the recording phase, the
        # record->replay boundary, and deep into stateful replay
        points = sorted(
            set(rng.integers(0, n_steps, size=3).tolist()) | {n_steps - 1}
        )
        for at in points:
            tokens, state, fleet = decode_stream(cfg, migrate_at=at)
            assert np.array_equal(tokens, base_tokens), f"tokens @ step {at}"
            assert state is not None and len(state) == len(base_state)
            for got, want in zip(state, base_state):
                assert np.array_equal(got, want), f"carried state @ step {at}"
            assert fleet.stats.migrations == 1
            assert fleet.locate("u0").name == "r1"
            assert fleet.replicas[1].edge.sessions_adopted == 1
            assert fleet.replicas[0].edge.sessions_migrated_out == 1

    def test_migration_transfers_env_over_backhaul(self):
        _, _, fleet = decode_stream(DENSE, migrate_at=6)
        assert fleet.stats.migration_bytes > 0
        assert fleet.backhaul.bytes_total >= fleet.stats.migration_bytes
        # the source box no longer holds the client's device memory
        assert "u0" not in fleet.replicas[0].edge.server.contexts

    def test_migration_to_self_is_noop(self):
        fleet = EdgeFleet(2)
        model, x = make_mlp()
        c = fleet.connect(model, client_id="u0", min_repeats=2)
        c.infer(x)
        assert fleet.migrate("u0", "r0") == "r0"
        assert fleet.stats.migrations == 0


class TestFaultInjection:
    def _warm_fleet(self, n=2, min_observations=4, **kw):
        fleet = EdgeFleet(n, min_observations=min_observations, **kw)
        model, x = make_mlp()
        client = fleet.connect(model, client_id="u0", min_repeats=3)
        for _ in range(6):   # past min_repeats AND min_observations
            client.infer(x)
        assert client.session.client.mode == "replaying"
        return fleet, client, x

    def test_failed_replica_recovered_by_hedge(self):
        fleet, client, x = self._warm_fleet()
        fleet.replica("r0").failed = True
        res = client.infer(x)
        assert res is not None
        assert fleet.router.stats.failures_recovered == 1
        # the client is permanently re-homed off the dead box
        assert client.primary == "r1"
        fleet.replica("r0").failed = False
        client.infer(x)
        assert client.primary == "r1", "no flap back after recovery"

    def test_all_replicas_failed_is_typed(self):
        fleet, client, x = self._warm_fleet()
        for rep in fleet.replicas:
            rep.failed = True
        with pytest.raises(AllReplicasFailedError):
            client.infer(x)
        # typed for callers that catch the broader placement error too
        assert issubclass(AllReplicasFailedError, NoHealthyReplicaError)
        assert issubclass(AllReplicasFailedError, RuntimeError)
        with pytest.raises(NoHealthyReplicaError):
            fleet.connect(make_mlp(seed=1)[0], client_id="u1")

    def test_cold_replica_adopts_replicated_fingerprint(self):
        """A hedge landing on a cold replica must not pay the full
        ``min_repeats`` Operator Sequence Search again: the fingerprint
        arrives through cache replication and one recorded inference locks
        the backup session straight into replay."""
        fleet, client, x = self._warm_fleet()
        fleet.replica("r0").slowdown = lambda i: 10.0   # force the hedge
        res, _, winner = client.dispatch(x)
        assert winner == "r1"
        backup = client.sessions["r1"]
        assert backup.client.cache_adopted is True
        assert backup.client.mode == "replaying"
        assert len(backup.history) == 1                 # one recorded call
        assert backup.history[0].mode == "recording"
        # hedged execution of a stateless request is bitwise-reproducible
        m = client.model
        want = np.asarray(m.apply(m.params, x)[0])
        assert np.array_equal(np.asarray(res.outputs[0]), want)

    def test_stateful_sessions_never_fork(self):
        """A live stateful replay step is non-idempotent (it advances the
        donated carried state) — a slow primary must NOT trigger a
        speculative duplicate; only outright failure moves it (by
        migration, which keeps the single home)."""
        fleet = EdgeFleet(2, min_observations=2)
        lm = RRTOServedLM(
            DENSE, edge=fleet.replicas[0].edge, client_id="u0", seed=0,
            min_repeats=2,
        )
        client = fleet.clients["u0"] = FleetClient(
            fleet, lm.session.model, "u0", lm.session, "r0", stateful=True,
        )
        g = lm.start_generation(PROMPT, max_new_tokens=6)
        for _ in range(4):   # lock replay, warm the deadline estimator
            client.infer(*lm.step_inputs(g))
            lm.absorb_step(g, client.session.history[-1].outputs)
        assert lm.session.client.stateful_replay
        fleet.replica("r0").slowdown = lambda i: 100.0
        _, _, winner = client.dispatch(*lm.step_inputs(g))
        assert winner == "r0", "slow stateful primary must not be hedged"
        assert len(client.sessions) == 1
        # outright failure DOES move it — via migration, not a fork
        fleet.replica("r0").failed = True
        _, _, winner = client.dispatch(*lm.step_inputs(g))
        assert winner == "r1"
        assert fleet.stats.migrations == 1
        assert len(client.sessions) == 1
        assert fleet.router.stats.failures_recovered == 1


class TestHedgedRouterFailureWalk:
    """Regression: when the primary AND the first hedge pick both fail, the
    router must walk every remaining healthy replica before declaring
    :class:`AllReplicasFailedError` — a third box can still serve."""

    def _router(self, fail_names, n=4):
        replicas = [
            ReplicaModel(name, 0.01, lambda i: 0.0)
            for name in ("a", "b", "c", "d")[:n]
        ]

        calls = []

        def complete(rep, idx):
            calls.append(rep.name)
            return None if rep.name in fail_names else 0.01

        return HedgedRouter(replicas, completion_source=complete), calls

    def test_third_replica_serves_after_double_failure(self):
        router, calls = self._router(fail_names={"a", "b"})
        t, winner = router.dispatch(0, primary=0)
        assert winner == "c"
        assert t > 0
        assert calls == ["a", "b", "c"], "walk in order, no extra duplicates"
        assert router.stats.failures_recovered == 1
        assert router.stats.hedged == 1

    def test_walk_reaches_the_last_healthy_replica(self):
        router, calls = self._router(fail_names={"a", "b", "c"})
        _, winner = router.dispatch(0, primary=0)
        assert winner == "d"
        assert calls == ["a", "b", "c", "d"]

    def test_exhausted_walk_raises_typed_error(self):
        router, calls = self._router(fail_names={"a", "b", "c", "d"})
        with pytest.raises(AllReplicasFailedError):
            router.dispatch(0, primary=0)
        assert sorted(calls) == ["a", "b", "c", "d"], "every box was tried"

    def test_success_path_pays_no_extra_dispatches(self):
        router, calls = self._router(fail_names=set())
        _, winner = router.dispatch(0, primary=0)
        assert winner == "a"
        assert calls == ["a"], "healthy primary: no hedge, no walk"


class TestStatefulDispatchFailures:
    """Typed placement/dispatch errors surfacing through FleetClient.dispatch
    mid-stream, with the donated carried state left uncorrupted."""

    def _stream(self, fleet, max_new=8):
        lm = RRTOServedLM(
            DENSE, edge=fleet.replicas[0].edge, client_id="u0", seed=0,
            min_repeats=2,
        )
        client = fleet.clients["u0"] = FleetClient(
            fleet, lm.session.model, "u0", lm.session, "r0", stateful=True,
        )
        g = lm.start_generation(PROMPT, max_new_tokens=max_new)
        return lm, client, g

    def test_all_replicas_failed_mid_stream_then_stream_resumes_bitwise(self):
        # reference: the same stream with no failures
        fleet0 = EdgeFleet(2, min_observations=4)
        lm0, c0, g0 = self._stream(fleet0)
        for _ in range(lm0.steps_total(g0)):
            c0.infer(*lm0.step_inputs(g0))
            lm0.absorb_step(g0, c0.session.history[-1].outputs)
        want_tokens = np.concatenate(g0["out"], axis=1)
        want_state = fleet0.locate("u0").edge.server.export_carried_state("u0")

        fleet = EdgeFleet(2, min_observations=4)
        lm, client, g = self._stream(fleet)
        n_steps = lm.steps_total(g)
        fail_at = n_steps - 3
        for step in range(n_steps):
            if step == fail_at:
                for rep in fleet.replicas:
                    rep.failed = True
                seq_before = client.session.client.step_seq
                with pytest.raises(AllReplicasFailedError):
                    client.dispatch(*lm.step_inputs(g))
                # typed for callers catching the broader placement error
                with pytest.raises(NoHealthyReplicaError):
                    client.dispatch(*lm.step_inputs(g))
                # the failed attempts never reached a server: the donated
                # state did not advance and the session did not move
                assert client.session.client.step_seq == seq_before
                assert client.primary == "r0"
                for rep in fleet.replicas:
                    rep.failed = False
            client.infer(*lm.step_inputs(g))
            lm.absorb_step(g, client.session.history[-1].outputs)
        tokens = np.concatenate(g["out"], axis=1)
        assert np.array_equal(tokens, want_tokens)
        state = fleet.locate("u0").edge.server.export_carried_state("u0")
        assert state is not None and len(state) == len(want_state)
        for got, want in zip(state, want_state):
            assert np.array_equal(got, want), "carried state uncorrupted"
        assert fleet.stats.migrations == 0, "no spurious moves on failure"

    def test_failed_primary_migrates_not_forks_under_walk(self):
        """Three replicas, primary dead: the stateful session migrates to a
        healthy box exactly once even though the router walks candidates."""
        fleet = EdgeFleet(3, min_observations=4)
        lm, client, g = self._stream(fleet)
        for _ in range(4):   # lock replay, warm the estimator
            client.infer(*lm.step_inputs(g))
            lm.absorb_step(g, client.session.history[-1].outputs)
        assert lm.session.client.stateful_replay
        fleet.replica("r0").failed = True
        _, _, winner = client.dispatch(*lm.step_inputs(g))
        assert winner in ("r1", "r2")
        assert client.primary == winner
        assert len(client.sessions) == 1, "single-home: migrated, not forked"
        assert fleet.stats.migrations == 1


class TestCrashRecovery:
    """A crashed replica lost its memory: the session restores from the
    last carried-state checkpoint on a peer and replays the logged steps."""

    def _stream(self, fault, ckpt_dir, max_new=8):
        fleet = EdgeFleet(
            2, hedging=False, min_observations=4, fault=fault,
            checkpoint_dir=str(ckpt_dir), checkpoint_every=3,
        )
        lm = RRTOServedLM(
            DENSE, edge=fleet.replicas[0].edge, client_id="u0", seed=0,
            min_repeats=2,
        )
        fc = fleet.clients["u0"] = FleetClient(
            fleet, lm.session.model, "u0", lm.session, "r0", stateful=True,
        )
        fleet.checkpointer.attach(lm.session.client)
        g = lm.start_generation(PROMPT, max_new_tokens=max_new)
        ts = []
        for _ in range(lm.steps_total(g)):
            res, _, _ = fc.dispatch(*lm.step_inputs(g))
            lm.absorb_step(g, res.outputs)
            ts.append(fleet.clock.t)
        tokens = np.concatenate(g["out"], axis=1)
        state = fleet.locate("u0").edge.server.export_carried_state("u0")
        return fleet, tokens, state, ts

    def test_mid_decode_crash_restores_bitwise(self, tmp_path):
        from repro.core.netsim import FaultInjector

        _, want_tokens, want_state, ts = self._stream(
            None, tmp_path / "clean"
        )
        # crash between two step boundaries, late enough that a checkpoint
        # exists and >= 1 logged step postdates it (a crash-only injector
        # leaves pre-crash timing identical, so clean boundaries place it)
        k = len(ts) - 3
        fault = FaultInjector(seed=5, crashes={"r0": 0.5 * (ts[k - 1] + ts[k])})
        fleet, tokens, state, _ = self._stream(fault, tmp_path / "faulted")
        assert fleet.stats.crashes == 1
        assert fleet.stats.crash_restores == 1
        assert fleet.stats.checkpoints >= 1
        assert fleet.stats.steps_replayed >= 1
        assert fleet.clients["u0"].primary == "r1"
        assert fleet.is_crashed("r0")
        assert np.array_equal(tokens, want_tokens)
        assert state is not None and len(state) == len(want_state)
        for got, want in zip(state, want_state):
            assert np.array_equal(got, want)
        # the checkpoint write was billed on the site backhaul
        assert fleet.stats.checkpoint_bytes > 0
        assert fleet.backhaul.bytes_total >= fleet.stats.checkpoint_bytes

    def test_recover_without_checkpoint_is_typed(self, tmp_path):
        fleet = EdgeFleet(
            2, min_observations=4, checkpoint_dir=str(tmp_path),
        )
        model, x = make_mlp()
        fleet.connect(model, client_id="u0", min_repeats=2)
        with pytest.raises(RuntimeError, match="checkpoint"):
            fleet.recover("u0")


class TestHedgedRouterWindow:
    def test_observation_window_bounded_over_10k_dispatches(self):
        replicas = [
            ReplicaModel("a", 0.010, lambda i: 0.0),
            ReplicaModel("b", 0.012, lambda i: 0.0),
        ]
        router = HedgedRouter(replicas, window=64)
        for i in range(10_000):
            router.dispatch(i)
        assert router.stats.requests == 10_000
        # the regression this pins: _observed grew one entry per dispatch
        assert router.observed_count == 64
        assert len(router._observed) <= 64
        # default-constructed routers get the module-level bound
        default = HedgedRouter(replicas)
        for i in range(OBSERVATION_WINDOW + 50):
            default.dispatch(i)
        assert default.observed_count == OBSERVATION_WINDOW

    def test_deadline_tracks_recent_distribution(self):
        """The bounded window must also keep the deadline *adaptive*: after
        a latency regime shift, old samples age out instead of freezing the
        deadline on stale history."""
        shift = 3_000
        replicas = [
            ReplicaModel("a", 0.0, lambda i: 0.01 if i < shift else 0.1),
        ]
        router = HedgedRouter(replicas, window=64)
        for i in range(shift + 200):
            router.dispatch(i)
        assert router._deadline() == pytest.approx(2.0 * 0.1)

    def test_window_validation(self):
        with pytest.raises(ValueError):
            HedgedRouter([ReplicaModel("a", 0.01, lambda i: 0.0)], window=0)


class _FakeProgram:
    """Stands in for a compiled ReplayProgram in cache-persistence tests."""

    def __init__(self, nbytes=100, carried_pairs=None, plan_sig=None):
        self.nbytes_estimate = nbytes
        self.n_kernels = 3
        self.total_flops = 1.0e6
        self.total_bytes = 2048.0
        self.d2h_avals = [((1, 8), "float32")]
        if carried_pairs is not None:
            self.carried_pairs = carried_pairs
        if plan_sig is not None:
            class _Plan:
                @staticmethod
                def signature():
                    return plan_sig
            self.plan = _Plan()


class TestCacheReplication:
    """ReplayCache.save/load as the fleet's replication primitive."""

    def test_roundtrip_preserves_carried_pairs_and_plan_keys(self, tmp_path):
        src = ReplayCache(capacity=8)
        src.put("fpA", _FakeProgram(carried_pairs=[(2, 0), (3, 1)]))
        src.put("fpA|cut=3", _FakeProgram(carried_pairs=[(2, 0)],
                                          plan_sig="cut=3"))
        src.put("fpA#vmap4", _FakeProgram())   # derived batched executable
        path = os.path.join(tmp_path, "cache.json")
        assert src.save(path) == 2             # '#' keys never persist

        dst = ReplayCache(capacity=8)
        assert dst.load(path) == 2
        assert "fpA" in dst and "fpA|cut=3" in dst
        assert "fpA#vmap4" not in dst
        assert len(dst) == 2
        # metadata carries the donation binding and the split plan — the
        # receiving replica rebuilds stateful/segmented, not stateless
        assert dst.known_metadata("fpA")["carried_pairs"] == [[2, 0], [3, 1]]
        assert dst.known_metadata("fpA|cut=3")["plan"] == "cut=3"
        # known-but-uncompiled: membership true, executable still a miss
        assert dst.get("fpA") is None
        assert dst.stats.misses == 1
        # replication chains: a re-save of the loaded cache keeps the fps
        path2 = os.path.join(tmp_path, "cache2.json")
        assert dst.save(path2) == 2

    def test_loaded_cache_honors_claims_under_eviction(self, tmp_path):
        src = ReplayCache(capacity=8)
        src.put("fpA", _FakeProgram(carried_pairs=[(0, 0)]))
        path = os.path.join(tmp_path, "cache.json")
        src.save(path)

        dst = ReplayCache(capacity=1)
        dst.load(path)
        dst.put("fpA", _FakeProgram(carried_pairs=[(0, 0)]))
        # a claim on the *derived* key pins the base for an in-flight round
        dst.claim("fpA|cut=3")
        dst.claim("fpA|cut=3")                  # claims nest
        dst.put("fpB", _FakeProgram())
        assert "fpA" in dst.fingerprints, "claimed base must not evict"
        assert "fpB" not in dst.fingerprints    # admission control instead
        dst.release("fpA|cut=3")
        dst.put("fpB", _FakeProgram())
        assert "fpA" in dst.fingerprints, "still one claim outstanding"
        dst.release("fpA|cut=3")
        dst.put("fpB", _FakeProgram())
        assert dst.fingerprints == ["fpB"], "released base evicts normally"
        # eviction dropped the program, not the validated identity
        assert "fpA" in dst

    def test_fleet_replicates_fingerprints_everywhere(self):
        fleet = EdgeFleet(3, min_observations=4)
        model, x = make_mlp()
        client = fleet.connect(model, client_id="u0", min_repeats=2)
        for _ in range(3):
            client.infer(x)
        fp = client.session.client.ios_fp
        assert fp is not None
        # _note_lock replicated eagerly at lock time
        for rep in fleet.replicas:
            assert fp in rep.edge.cache
        assert fleet.stats.replicated_fingerprints >= 1
        assert fleet.stats.cache_syncs >= 1


class TestFleetPlumbing:
    def test_multi_node_ingress_shares_backhaul(self):
        pipes = multi_node_ingress(
            3, node_capacity_bytes_per_s=100.0, backhaul_bytes_per_s=240.0
        )
        assert len(pipes) == 3
        assert all(p.backhaul is pipes[0].backhaul for p in pipes)
        # per-node NIC would give 100, but the site uplink caps at 240/3
        assert pipes[0].share() == pytest.approx(80.0)
        pipes[0].account(50.0)
        pipes[1].account(25.0)
        assert pipes[0].bytes_total == 50.0
        assert pipes[1].bytes_total == 25.0
        assert pipes[0].backhaul.bytes_total == 75.0
        with pytest.raises(ValueError):
            multi_node_ingress(0)

    def test_affinity_placement(self):
        fleet = EdgeFleet(2)
        m0, _ = make_mlp(0)
        c0 = fleet.connect(m0, client_id="a")
        c1 = fleet.connect(m0, client_id="b")     # same model co-locates
        assert c0.primary == c1.primary
        assert fleet.stats.affinity_hits == 1
        m1, _ = make_mlp(1)
        c2 = fleet.connect(m1, client_id="c")     # new model balances away
        assert c2.primary != c0.primary

    def test_serve_open_loop_on_timeline(self):
        fleet = EdgeFleet(2, min_observations=4)
        model, x = make_mlp()
        client = fleet.connect(model, client_id="u0", min_repeats=2)
        for _ in range(3):
            client.infer(x)
        reqs = [(0.001 * (k + 1), "u0", (x,)) for k in range(5)]
        results = fleet.serve(reqs)
        assert len(results) == 5
        assert fleet.timeline.fired == 10         # arrival + completion each
        for r in results:
            assert r.latency_seconds > 0
            assert r.winner in ("r0", "r1")
            assert r.done_at == pytest.approx(r.arrival_t + r.latency_seconds)
