import os
import sys

# tests run on the single real CPU device (the 512-device override is applied
# ONLY inside launch/dryrun.py, never globally)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root, so tests can drive the benchmarks (e.g. the partition sweep)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
