"""Distributed utilities: sharding translation, ZeRO-1 spec derivation,
int8 gradient compression with error feedback, straggler mitigation."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.compression import (
    compressed_psum,
    dequantize_int8,
    quantize_int8,
)
from repro.distributed.sharding import (
    compat_make_mesh,
    get_shard_map,
    translate_spec,
    zero1_spec,
)
from repro.distributed.straggler import (
    HedgedRouter,
    ReplicaModel,
    SkipAndRescale,
)


class TestShardingTranslate:
    def test_logical_axes(self):
        assert translate_spec(P("dp", None, "tp"), ("data", "model")) == P(
            "data", None, "model"
        )
        assert translate_spec(P("dp", "tp"), ("pod", "data", "model")) == P(
            ("pod", "data"), "model"
        )

    def test_unknown_axis_dropped(self):
        assert translate_spec(P("tp"), ("data",)) == P(None)

    def test_zero1_adds_dp_on_first_divisible(self):
        assert zero1_spec(P(None, "tp"), (64, 128), 16) == P("dp", "tp")
        # first dim not divisible -> second
        assert zero1_spec(P(None, None), (7, 32), 16) == P(None, "dp")
        # nothing divisible -> unchanged
        assert zero1_spec(P(None,), (7,), 16) == P(None)


class TestCompression:
    def test_quantize_roundtrip_error_bound(self, rng):
        x = jnp.asarray(rng.normal(0, 1, (128,)).astype(np.float32))
        q, scale = quantize_int8(x)
        err = jnp.abs(dequantize_int8(q, scale) - x).max()
        assert float(err) <= float(scale) * 0.5 + 1e-6

    def test_compressed_psum_shard_map(self, rng):
        mesh = compat_make_mesh((1,), ("data",))
        x = jnp.asarray(rng.normal(0, 1, (64,)).astype(np.float32))

        shard_map = get_shard_map()

        f = shard_map(
            lambda v: compressed_psum(v, "data")[0],
            mesh=mesh,
            in_specs=P(None),
            out_specs=P(None),
        )
        out = f(x)
        # single shard: mean == dequantized self
        q, s = quantize_int8(x)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(dequantize_int8(q, s)), rtol=1e-6
        )

    def test_error_feedback_converges(self, rng):
        """Repeated compressed reductions of the same gradient with error
        feedback: the accumulated applied update converges to the true sum."""
        x = jnp.asarray(rng.normal(0, 1, (256,)).astype(np.float32))
        err = jnp.zeros_like(x)
        applied = jnp.zeros_like(x)
        mesh = compat_make_mesh((1,), ("data",))
        shard_map = get_shard_map()

        step = shard_map(
            lambda v, e: compressed_psum(v, "data", e),
            mesh=mesh, in_specs=(P(None), P(None)), out_specs=(P(None), P(None)),
        )
        n = 50
        for _ in range(n):
            out, err = step(x, err)
            applied = applied + out
        np.testing.assert_allclose(
            np.asarray(applied) / n, np.asarray(x), rtol=0, atol=2e-2
        )

    def test_wire_bytes_reduction(self):
        x = jnp.zeros((1024,), jnp.float32)
        q, _ = quantize_int8(x)
        assert q.dtype == jnp.int8 and q.nbytes * 4 == x.nbytes


class TestStraggler:
    def test_hedge_cuts_tail(self):
        def spiky(i):
            return 0.5 if i % 10 == 3 else 0.0

        replicas = [
            ReplicaModel("a", 0.010, spiky),
            ReplicaModel("b", 0.010, lambda i: 0.0),
            ReplicaModel("c", 0.010, lambda i: 0.0),
        ]
        router = HedgedRouter(replicas, hedge_multiplier=2.0)
        for i in range(300):
            router.dispatch(i)
        assert router.stats.hedged > 0
        assert router.stats.p99 < 0.2  # without hedging p99 would be ~0.51

    def test_failed_replica_recovered(self):
        replicas = [
            ReplicaModel("dead", 0.01, lambda i: 0.0, failed=True),
            ReplicaModel("alive", 0.01, lambda i: 0.0),
        ]
        router = HedgedRouter(replicas, hedge_multiplier=2.0)
        for i in range(20):
            t, winner = router.dispatch(i)
            assert winner == "alive"

    def test_skip_and_rescale(self):
        pol = SkipAndRescale(world=10, quorum_fraction=0.8)
        ok, scale = pol.step([True] * 9 + [False])
        assert ok and scale == pytest.approx(10 / 9)
        ok, _ = pol.step([True] * 7 + [False] * 3)
        assert not ok
