"""Elastic rescale: a checkpoint saved from a 4-device (2x2) mesh restores
onto a 2-device (2x1) mesh with different shardings and identical values —
the restart-after-topology-change path.  Runs in subprocesses so the main
test process keeps the single real device."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

_SAVE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, os.environ["REPRO_SRC"])
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import store

    from repro.distributed.sharding import compat_make_mesh
    mesh = compat_make_mesh((2, 2), ("data", "model"))
    w = jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32)
    sharded = jax.device_put(w, NamedSharding(mesh, P("data", "model")))
    tree = {"params": {"w": sharded}, "step": jnp.int32(9)}
    store.save(os.environ["CKPT_DIR"], 9, tree)
    print("SAVED", sharded.sharding)
    """
)

_RESTORE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import sys
    sys.path.insert(0, os.environ["REPRO_SRC"])
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import store

    from repro.distributed.sharding import compat_make_mesh
    mesh = compat_make_mesh((2, 1), ("data", "model"))
    target = {
        "params": {"w": jax.ShapeDtypeStruct((64, 32), jnp.float32)},
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    shardings = {
        "params": {"w": NamedSharding(mesh, P("data", "model"))},
        "step": NamedSharding(mesh, P()),
    }
    step = store.latest_step(os.environ["CKPT_DIR"])
    assert step == 9, step
    restored = store.restore(os.environ["CKPT_DIR"], step, target,
                             shardings=shardings)
    w = restored["params"]["w"]
    assert len(w.sharding.device_set) == 2, w.sharding
    expected = np.arange(64 * 32, dtype=np.float32).reshape(64, 32)
    np.testing.assert_array_equal(np.asarray(w), expected)
    assert int(restored["step"]) == 9
    print("RESTORED OK on", len(jax.devices()), "devices")
    """
)


@pytest.mark.timeout(300)
def test_elastic_reshard_across_device_counts(tmp_path):
    env = dict(os.environ)
    env["REPRO_SRC"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    env["CKPT_DIR"] = str(tmp_path)
    env.pop("XLA_FLAGS", None)
    for script, marker in ((_SAVE, "SAVED"), (_RESTORE, "RESTORED OK")):
        out = subprocess.run(
            [sys.executable, "-c", script], env=env,
            capture_output=True, text=True, timeout=280,
        )
        assert out.returncode == 0, f"stdout={out.stdout}\nstderr={out.stderr[-2000:]}"
        assert marker in out.stdout
