"""Stateful split replay — carried-pinned partitioning of KV-cached IOSes.

The acceptance property: for ANY carried-feasible plan, segmented
device/server execution with the donated stateful server suffix is bitwise
identical to the stateful full-server replay, step for step, across registry
models including the KV-cached decode workload.  Plus: feasibility edge
cases (no feasible device prefix -> full-server endpoint, not an exception),
persistence round-trips rebuilding both carried_pairs and the plan
signature, plan-swap state continuity, the split-aware DAM fallback state
download, pipelined stateful streaming, and co-tenant segment batching with
per-client state isolation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core.engine import (
    BoundSegmentedReplay,
    SegmentedReplayProgram,
    _quiet_donation,
)
from repro.core.offload import OffloadableModel, OffloadSession
from repro.models.cnn_zoo import make_recurrent_sensor_decoder
from repro.partition import (
    PLACE_DEVICE,
    PLACE_SERVER,
    PartitionConfig,
    SegmentGraph,
    SplitPlan,
    plan_partition,
)
from repro.serving.engine import RRTOServedLM

MBPS = 1e6 / 8.0

DECODE_CFG = ArchConfig(
    name="t", family="dense", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_head=16, d_ff=128, vocab=256, dtype="float32", rope_theta=1e4,
)


def make_rnn(seed=0, d=8, batch=2):
    """An RNN with a stateless input encoder (the prologue a split can keep
    on the device) ahead of the carried-state cell."""
    rng = np.random.default_rng(seed)
    params = {
        "w_in": rng.normal(0, 0.1, (d, d)).astype(np.float32),
        "w": rng.normal(0, 0.1, (d, d)).astype(np.float32),
    }

    def apply(p, x, state):
        z = jnp.tanh(x @ p["w_in"])             # stateless prologue
        new_state = jnp.tanh(state @ p["w"] + z)
        return [new_state.sum(axis=1), new_state]

    x = rng.normal(0, 1, (batch, d)).astype(np.float32)
    state0 = np.zeros((batch, d), np.float32)
    return OffloadableModel(f"rnn{seed}", apply, params, (x, state0)), x, state0


def lock_stateful_session(model, inputs, state_in=1, state_out=1, steps=5,
                          min_repeats=3, **session_kwargs):
    """Drive a stateful app to replay lock, threading the carried state
    (input position ``state_in`` <- output position ``state_out``)."""
    sess = OffloadSession(model, "rrto", min_repeats=min_repeats,
                          **session_kwargs)
    sess.load()
    args = list(inputs)
    for _ in range(steps):
        res = sess.infer(*args)
        args[state_in] = res.outputs[state_out]
    assert sess.client.mode == "replaying", "IOS never locked"
    assert sess.client.stateful_replay, "carried state not detected"
    return sess


def lock_decode_session(new_tokens=8):
    """The KV-cached decode workload: an offloaded LLM decode_step whose
    cache pytree is loop-carried."""
    prompt = np.random.default_rng(0).integers(0, 256, (1, 4)).astype(np.int32)
    served = RRTOServedLM(DECODE_CFG, bucket_len=16, batch=1, seed=3,
                          min_repeats=3)
    served.generate(prompt, new_tokens)
    sess = served.session
    assert sess.client.mode == "replaying"
    assert sess.client.stateful_replay
    return sess


def feasible_plans(graph, max_plans=4):
    """A spread of carried-feasible device-prefix/server-suffix plans."""
    limit = graph.carried_cut_limit()
    n = graph.n_ops
    bmax = min(limit, n - 1)
    if bmax < 1:
        return []
    bounds = sorted({1, max(1, bmax // 2), bmax})[:max_plans]
    return [
        SplitPlan.from_placements(
            [PLACE_DEVICE] * b + [PLACE_SERVER] * (n - b)
        )
        for b in bounds
    ]


def snapshot_state(sess):
    ctx = sess.server.context(sess.client_id)
    src = ctx.split if ctx.split is not None else ctx.replay
    return [np.array(np.asarray(s), copy=True) for s in src.carried_state]


class TestStatefulSplitEquivalence:
    """Acceptance property: stateful split replay is bitwise identical to
    stateful full-server replay, step for step, across >= 2 registry models
    including the KV-cached decode workload."""

    def _assert_bitwise(self, sess, steps=4):
        client = sess.client
        calls = client._ios_calls
        pairs = client.ios.carried_pairs
        ctx = sess.server.context(sess.client_id)
        env = ctx.env
        ref_bound = ctx.replay
        program = ref_bound.program
        params_flat = [env[a] for a in ref_bound.param_addrs]
        state0 = [
            np.array(np.asarray(s), copy=True)
            for s in ref_bound.carried_state
        ]
        wire = sess.replay_wire_inputs(sess.model.example_inputs)

        graph = SegmentGraph(calls, carried_pairs=pairs)
        plans = feasible_plans(graph)
        assert plans, "no feasible device prefix in this workload"
        for plan in plans:
            prog = SegmentedReplayProgram(calls, plan, carried_pairs=pairs)
            bound = BoundSegmentedReplay.from_own(prog)
            bound.carried_state = [jnp.asarray(s) for s in state0]
            ref_state = [jnp.asarray(s) for s in state0]
            split_env = dict(env)
            for step in range(steps):
                with _quiet_donation():
                    ref_outs, ref_state = program.step_fn(
                        params_flat, [np.asarray(w) for w in wire], ref_state
                    )
                ref_state = list(ref_state)
                outs = bound.execute(wire, split_env)
                assert len(outs) == len(ref_outs)
                for got, want in zip(outs, ref_outs):
                    assert np.array_equal(
                        np.asarray(got), np.asarray(want)
                    ), f"plan {plan.signature()} diverged at step {step}"
                for got, want in zip(bound.carried_state, ref_state):
                    assert np.array_equal(
                        np.asarray(got), np.asarray(want)
                    ), f"plan {plan.signature()} state diverged at {step}"

    def test_rnn_bitwise(self):
        model, x, state0 = make_rnn()
        sess = lock_stateful_session(model, (x, state0))
        self._assert_bitwise(sess)

    def test_recurrent_sensor_decoder_bitwise(self):
        model = make_recurrent_sensor_decoder(
            scale=0.25, input_size=32, n_blocks=2, d_state=32
        )
        sess = lock_stateful_session(
            model, model.example_inputs, min_repeats=2
        )
        self._assert_bitwise(sess)

    def test_kv_cached_decode_bitwise(self):
        """The decode workload: every KV-cache leaf is loop-carried; the
        split suffix advances the whole cache pytree in place."""
        sess = lock_decode_session()
        assert len(sess.client.ios.carried_pairs) >= 2  # a cache pytree
        self._assert_bitwise(sess, steps=3)

    def test_rebinding_across_clients(self):
        """A stateful segmented program compiled from one client's calls
        executes correctly bound to a second client's address space, with
        the second client's own carried state."""
        model, x, state0 = make_rnn()
        sess_a = lock_stateful_session(model, (x, state0))
        sess_b = lock_stateful_session(model, (x, state0), seed=5)
        pairs = sess_a.client.ios.carried_pairs
        graph = SegmentGraph(sess_a.client._ios_calls, carried_pairs=pairs)
        plan = feasible_plans(graph)[-1]
        prog = SegmentedReplayProgram(
            sess_a.client._ios_calls, plan, carried_pairs=pairs
        )
        bound = BoundSegmentedReplay.bind(prog, sess_b.client._ios_calls)
        env_b = sess_b.server.context(sess_b.client_id).env
        bound.seed_carried(env_b)
        assert bound.carried_state is not None
        ref_bound = sess_b.server.context(sess_b.client_id).replay
        state0_b = [
            np.array(np.asarray(s), copy=True)
            for s in ref_bound.carried_state
        ]
        bound.carried_state = [jnp.asarray(s) for s in state0_b]
        wire = sess_b.replay_wire_inputs(model.example_inputs)
        params_flat = [env_b[a] for a in ref_bound.param_addrs]
        with _quiet_donation():
            ref_outs, _ = ref_bound.program.step_fn(
                params_flat, [np.asarray(w) for w in wire],
                [jnp.asarray(s) for s in state0_b],
            )
        outs = bound.execute(wire, env_b)
        for got, want in zip(outs, ref_outs):
            assert np.array_equal(np.asarray(got), np.asarray(want))


class TestCarriedFeasibility:
    def test_first_op_carried_returns_full_server(self):
        """An IOS whose FIRST op consumes carried state has no feasible
        device prefix: the planner must return the full-server endpoint,
        not raise."""
        rng = np.random.default_rng(0)
        params = {"w": rng.normal(0, 0.1, (8, 8)).astype(np.float32)}

        def apply(p, state, x):
            z = state @ p["w"]          # op 0 consumes the carried state
            new_state = jnp.tanh(z + x)
            return [new_state.sum(axis=1), new_state]

        x = rng.normal(0, 1, (2, 8)).astype(np.float32)
        state0 = np.zeros((2, 8), np.float32)
        model = OffloadableModel("first_carried", apply, params, (state0, x))
        sess = lock_stateful_session(
            model, (state0, x), state_in=0, state_out=1,
            partition=PartitionConfig(),
        )
        client = sess.client
        graph = client.replanner.graph
        assert graph.carried_cut_limit() == 0
        ev = plan_partition(
            graph, sess.client_device, sess.server_device, 16 * MBPS
        )
        assert ev.plan.is_full_server
        # the live session holds the full-server endpoint, still correct
        assert client.split_plan is None
        f = jax.jit(model.apply)
        state_ref = jnp.asarray(state0)
        for _ in range(len(sess.history)):
            y_ref, state_ref = f(model.params, state_ref, x)
        state_arg = sess.history[-1].outputs[1]
        for _ in range(2):
            res = sess.infer(state_arg, x)
            state_arg = res.outputs[1]
            y_ref, state_ref = f(model.params, state_ref, x)
            np.testing.assert_allclose(
                np.asarray(res.outputs[0]), np.asarray(y_ref),
                rtol=1e-6, atol=1e-6,
            )

    def test_infeasible_plan_rejected_at_compile(self):
        model, x, state0 = make_rnn()
        sess = lock_stateful_session(model, (x, state0))
        pairs = sess.client.ios.carried_pairs
        calls = sess.client._ios_calls
        graph = SegmentGraph(calls, carried_pairs=pairs)
        n = graph.n_ops
        # device suffix strands the carried region on the device side
        bad = SplitPlan.from_placements(
            [PLACE_SERVER] * (n - 1) + [PLACE_DEVICE]
        )
        assert not graph.plan_carried_feasible(bad)
        with pytest.raises(ValueError, match="carried-feasible"):
            SegmentedReplayProgram(calls, bad, carried_pairs=pairs)

    def test_planner_only_feasible_plans_across_bandwidths(self):
        model = make_recurrent_sensor_decoder(
            scale=0.25, input_size=32, n_blocks=2, d_state=32
        )
        sess = lock_stateful_session(
            model, model.example_inputs, min_repeats=2
        )
        pairs = sess.client.ios.carried_pairs
        graph = SegmentGraph(sess.client._ios_calls, carried_pairs=pairs)
        for mbps in (0.5, 8.0, 64.0, 512.0):
            ev = plan_partition(
                graph, sess.client_device, sess.server_device, mbps * MBPS
            )
            assert graph.plan_carried_feasible(ev.plan)
            assert not ev.plan.is_full_device


class TestStatefulSplitSession:
    """End-to-end: a stateful session on an installed split plan keeps the
    state server-resident and its outputs bitwise-track the plain stateful
    session."""

    def _locked_pair(self):
        model = make_recurrent_sensor_decoder(
            scale=0.25, input_size=32, n_blocks=2, d_state=32
        )
        plain = lock_stateful_session(
            model, model.example_inputs, min_repeats=2, seed=0
        )
        split = lock_stateful_session(
            model, model.example_inputs, min_repeats=2, seed=0,
            partition=PartitionConfig(adaptive=False),
        )
        pairs = split.client.ios.carried_pairs
        graph = SegmentGraph(split.client._ios_calls, carried_pairs=pairs)
        plan = feasible_plans(graph)[-1]
        split.client._install_plan(plan)
        return model, plain, split, plan

    def test_outputs_match_plain_stateful(self):
        model, plain, split, plan = self._locked_pair()
        assert split.client.split_plan is not None
        frame = np.asarray(model.example_inputs[0])
        h_plain = plain.history[-1].outputs[1]
        h_split = split.history[-1].outputs[1]
        for _ in range(4):
            want = plain.infer(frame, h_plain)
            got = split.infer(frame, h_split)
            h_plain = want.outputs[1]
            h_split = got.outputs[1]
            assert np.array_equal(
                np.asarray(got.outputs[0]), np.asarray(want.outputs[0])
            )

    def test_state_never_crosses_on_split(self):
        """Steady split replay bills only the boundary tensors + wire
        output: neither the carried state nor the raw frame (held back by
        the device prefix) contributes wire bytes."""
        model, plain, split, plan = self._locked_pair()
        h = split.history[-1].outputs[1]
        frame = np.asarray(model.example_inputs[0])
        res1 = split.infer(frame, h)
        res2 = split.infer(frame, res1.outputs[1])
        # steady state: identical wire volume round over round, smaller
        # than the raw frame alone (let alone frame + state)
        assert res2.network_bytes == res1.network_bytes
        assert res2.network_bytes < frame.nbytes
        full = plain.infer(frame, plain.history[-1].outputs[1])
        # plain stateful full-server ships the whole frame; the split ships
        # the (much smaller) stem boundary — and neither ships the state
        assert res2.network_bytes < full.network_bytes

    def test_plan_swap_preserves_state(self):
        """Swapping split -> full-server -> split mid-session migrates the
        live carried state between the bindings: outputs keep tracking the
        single-plan reference."""
        model, plain, split, plan = self._locked_pair()
        frame = np.asarray(model.example_inputs[0])
        h_plain = plain.history[-1].outputs[1]
        h_split = split.history[-1].outputs[1]
        n = SegmentGraph(split.client._ios_calls).n_ops
        for swap_to in (SplitPlan.full_server(n), plan,
                        SplitPlan.full_server(n)):
            want = plain.infer(frame, h_plain)
            got = split.infer(frame, h_split)
            h_plain, h_split = want.outputs[1], got.outputs[1]
            assert np.array_equal(
                np.asarray(got.outputs[0]), np.asarray(want.outputs[0])
            )
            split.client._install_plan(swap_to)
        # one more round on the final plan
        want = plain.infer(frame, h_plain)
        got = split.infer(frame, h_split)
        assert np.array_equal(
            np.asarray(got.outputs[0]), np.asarray(want.outputs[0])
        )

    def test_fresh_state_reships_once_on_split(self):
        """Supplying genuinely new state mid-split-session overrides the
        server-resident suffix state (one extra RPC), like full-server."""
        model, plain, split, plan = self._locked_pair()
        frame = np.asarray(model.example_inputs[0])
        h = split.history[-1].outputs[1]
        steady = split.infer(frame, h)
        fresh = np.full_like(np.asarray(model.example_inputs[1]), 0.125)
        res = split.infer(frame, fresh)
        assert res.rpcs == steady.rpcs + 1
        f = jax.jit(model.apply)
        want_y, _ = f(model.params, frame, jnp.asarray(fresh))
        np.testing.assert_allclose(
            np.asarray(res.outputs[0]), np.asarray(want_y),
            rtol=1e-5, atol=1e-6,
        )


class TestStatefulSplitFallback:
    def test_materializer_reads_split_suffix_state(self):
        """After split steps, the live state lives in the split binding —
        the DAM materializer must download THAT, not the whole-program
        binding's stale lock-time snapshot."""
        model = make_recurrent_sensor_decoder(
            scale=0.25, input_size=32, n_blocks=2, d_state=32
        )
        sess = lock_stateful_session(
            model, model.example_inputs, min_repeats=2,
            partition=PartitionConfig(adaptive=False, pipelined=True),
        )
        client = sess.client
        pairs = client.ios.carried_pairs
        graph = SegmentGraph(client._ios_calls, carried_pairs=pairs)
        client._install_plan(feasible_plans(graph)[-1])
        assert client.pipelined_exec is not None
        frame = np.asarray(model.example_inputs[0])
        h = sess.history[-1].outputs[1]
        for _ in range(3):
            res = sess.infer(frame, h)
            h = res.outputs[1]
        ctx = sess.server.context(client.client_id)
        live = np.asarray(ctx.split.carried_state[0])
        stale = np.asarray(ctx.replay.carried_state[0])
        assert not np.array_equal(live, stale)  # split advanced past lock

        ph = client._carried_placeholders[0]
        h2d_calls = [
            c for c in client._ios_calls
            if c.record.func == "cudaMemcpyHtoD"
        ]
        carried_ordinal = next(iter(client._carried_in_map))
        client._replay_prefix = list(h2d_calls)
        client._replay_prefix[carried_ordinal].h2d_value = ph
        rpcs0 = client.stats.rpcs
        client._materialize_carried_prefix()
        assert client.stats.rpcs == rpcs0 + 1
        np.testing.assert_array_equal(ph, live)

    def test_dam_fallback_refreshes_handle_and_recovers(self):
        """End-to-end deviation on a pipelined stateful split session: the
        app-held handle is refreshed with the live state BEFORE the stream
        executor drops, and the post-fallback computation continues from the
        true state."""
        from repro.core.costmodel import GTX_2080TI
        from repro.core.energy import EnergyMeter
        from repro.core.engine import OffloadServer, RRTOClient, SimClock
        from repro.core.flatten import flatten_closed_jaxpr
        from repro.core.intercept import NO_NOISE, JaxprInterceptor
        from repro.core.netsim import indoor_network

        rng = np.random.default_rng(0)
        w = rng.normal(0, 0.1, (8, 8)).astype(np.float32)
        x = rng.normal(0, 1, (2, 8)).astype(np.float32)

        def graph_a(w, xx, state):
            z = jnp.tanh(xx @ w)
            new = jnp.tanh(z + state @ w)
            return [new.sum(axis=1), new]

        def graph_b(w, xx, state):
            z = jax.nn.relu(xx @ w)
            new = jnp.tanh(z + state)
            return [new.sum(axis=1), new]

        state0 = np.zeros((2, 8), np.float32)
        ja = flatten_closed_jaxpr(
            jax.make_jaxpr(lambda xx, st: graph_a(w, xx, st))(x, state0)
        )
        jb = flatten_closed_jaxpr(
            jax.make_jaxpr(lambda xx, st: graph_b(w, xx, st))(x, state0)
        )
        client = RRTOClient(
            OffloadServer(GTX_2080TI, execute=True),
            indoor_network(), SimClock(), EnergyMeter(),
            variant="rrto", min_repeats=2,
            partition=PartitionConfig(adaptive=False, pipelined=True),
        )
        icp = JaxprInterceptor(client, NO_NOISE)
        addrs_a = icp.upload_params([np.asarray(c) for c in ja.consts])
        addrs_b = icp.upload_params([np.asarray(c) for c in jb.consts])
        state = state0
        for _ in range(5):
            outs = icp.run(ja, addrs_a, [x, state])
            state = outs[1]
        assert client.mode == "replaying" and client.stateful_replay
        pairs = client.ios.carried_pairs
        graph = SegmentGraph(client._ios_calls, carried_pairs=pairs)
        plans = feasible_plans(graph)
        if plans:
            client._install_plan(plans[-1])
        # a few split/stateful replay rounds advance the server state
        for _ in range(3):
            outs = icp.run(ja, addrs_a, [x, state])
            state = outs[1]
        # the reference trajectory the server should be holding
        fa = jax.jit(lambda xx, st: graph_a(w, xx, st))
        ref_state = jnp.asarray(state0)
        for _ in range(8):
            _, ref_state = fa(x, ref_state)
        # deviate: graph B starts with the same H2D uploads, so the carried
        # upload sits in the replayed prefix when the first kernel diverges
        outs_b = icp.run(jb, addrs_b, [x, state])
        assert client.fallbacks >= 1 and client.mode == "recording"
        assert client.pipelined_exec is None
        # the app's handle was refreshed in place with the live state
        # (fused-jit reference vs per-op replay: float32 drift over the
        # 8-step trajectory, hence the loose tolerance)
        np.testing.assert_allclose(
            np.asarray(state), np.asarray(ref_state), rtol=1e-3, atol=1e-4
        )
        fb = jax.jit(lambda xx, st: graph_b(w, xx, st))
        want_b, _ = fb(x, ref_state)
        np.testing.assert_allclose(
            np.asarray(outs_b[0]), np.asarray(want_b), rtol=1e-3, atol=1e-4
        )


class TestStatefulPipelinedStream:
    def test_stream_bitwise_equals_sequential_split(self):
        """infer_stream over a stateful split plan advances the suffix state
        per submission, in order — outputs bitwise equal the sequential
        split session's trajectory."""
        model = make_recurrent_sensor_decoder(
            scale=0.25, input_size=32, n_blocks=2, d_state=32
        )
        seq = lock_stateful_session(
            model, model.example_inputs, min_repeats=2, seed=0,
            partition=PartitionConfig(adaptive=False),
        )
        piped = lock_stateful_session(
            model, model.example_inputs, min_repeats=2, seed=0,
            partition=PartitionConfig(adaptive=False, pipelined=True),
        )
        pairs = piped.client.ios.carried_pairs
        graph = SegmentGraph(piped.client._ios_calls, carried_pairs=pairs)
        plan = feasible_plans(graph)[-1]
        seq.client._install_plan(plan)
        piped.client._install_plan(plan)
        assert piped.client.pipelined_exec is not None

        rng = np.random.default_rng(3)
        frames = [
            np.asarray(model.example_inputs[0])
            + rng.normal(0, 0.01, np.shape(model.example_inputs[0])).astype(
                np.float32
            )
            for _ in range(4)
        ]
        h_seq = seq.history[-1].outputs[1]
        # the app threads the stable handle through the stream, exactly as
        # it would through sequential infer() calls
        h_piped = piped.history[-1].outputs[1]
        results = piped.infer_stream([(f, h_piped) for f in frames])
        assert len(results) == len(frames)
        assert all(
            a.done_at <= b.done_at for a, b in zip(results, results[1:])
        )
        for r, f in zip(results, frames):
            want = seq.infer(f, h_seq)
            h_seq = want.outputs[1]
            # same arity and meaning as sequential infer(): [y, state handle]
            assert len(r.outputs) == len(want.outputs)
            assert r.outputs[1] is h_piped
            assert np.array_equal(
                np.asarray(r.outputs[0]), np.asarray(want.outputs[0])
            )

    def test_stream_fresh_state_override(self):
        """A non-handle state value in a stream arrival overwrites the
        server-resident suffix state (one extra billed RPC), matching the
        sequential fresh-state semantics — it must not be silently dropped."""
        model = make_recurrent_sensor_decoder(
            scale=0.25, input_size=32, n_blocks=2, d_state=32
        )
        sess = lock_stateful_session(
            model, model.example_inputs, min_repeats=2, seed=0,
            partition=PartitionConfig(adaptive=False, pipelined=True),
        )
        pairs = sess.client.ios.carried_pairs
        graph = SegmentGraph(sess.client._ios_calls, carried_pairs=pairs)
        sess.client._install_plan(feasible_plans(graph)[-1])
        frame = np.asarray(model.example_inputs[0])
        fresh = np.full_like(np.asarray(model.example_inputs[1]), 0.25)
        rpcs0 = sess.client.stats.rpcs
        results = sess.infer_stream([(frame, fresh)])
        assert sess.client.stats.rpcs > rpcs0  # override + boundary traffic
        # a fresh upload mints a new handle (like the sequential path); the
        # app threads it into the next stream window
        new_handle = results[0].outputs[1]
        results2 = sess.infer_stream([(frame, new_handle)])
        f = jax.jit(model.apply)
        y1, h1 = f(model.params, frame, jnp.asarray(fresh))
        y2, _ = f(model.params, frame, h1)
        np.testing.assert_allclose(
            np.asarray(results[0].outputs[0]), np.asarray(y1),
            rtol=1e-5, atol=1e-6,
        )
        np.testing.assert_allclose(
            np.asarray(results2[0].outputs[0]), np.asarray(y2),
            rtol=1e-5, atol=1e-6,
        )


class TestStreamExecutorClaims:
    def test_installed_stream_executor_pins_its_base(self):
        """While a pipelined stream executor is installed, its derived
        fp|plan key holds a cache claim pinning the base program; reverting
        to full-server (or a DAM fallback) releases it."""
        from repro.serving.multitenant import RRTOEdgeServer

        model = make_recurrent_sensor_decoder(
            scale=0.25, input_size=32, n_blocks=2, d_state=32
        )
        edge = RRTOEdgeServer(execute=True)
        sess = edge.connect(
            model, min_repeats=2,
            partition=PartitionConfig(adaptive=False, pipelined=True),
        )
        state = np.asarray(model.example_inputs[1])
        frame = np.asarray(model.example_inputs[0])
        for _ in range(4):
            res = edge.run_round({"c0": (frame, state)})["c0"]
            state = res.outputs[1]
        client = sess.client
        assert client.mode == "replaying"
        edge.batcher.begin_round({})  # expire the last round's claims
        pairs = client.ios.carried_pairs
        graph = SegmentGraph(client._ios_calls, carried_pairs=pairs)
        plan = feasible_plans(graph)[-1]
        client._install_plan(plan)
        assert client.pipelined_exec is not None
        fp = client.ios_fp
        assert client._stream_claim == f"{fp}|{plan.signature()}"
        assert edge.cache.is_pinned(fp)
        n = graph.n_ops
        client._install_plan(SplitPlan.full_server(n))
        assert client.pipelined_exec is None
        assert client._stream_claim is None
        assert not edge.cache.is_pinned(fp)


class TestStatefulSplitPersistence:
    def test_split_plan_roundtrip_rebuilds_state_and_signature(self, tmp_path):
        """ReplayCache.save/load of a stateful split entry: the fp|plan key
        persists both the plan signature and the carried pairs, and a
        restarted server's prepare_split rebuilds a *stateful* segmented
        program from metadata alone."""
        from repro.serving.replay_cache import ReplayCache

        model, x, state0 = make_rnn()
        sess = lock_stateful_session(model, (x, state0))
        client = sess.client
        pairs = client.ios.carried_pairs
        calls = client._ios_calls
        graph = SegmentGraph(calls, carried_pairs=pairs)
        plan = feasible_plans(graph)[-1]

        server = sess.server
        server.replay_cache = cache = ReplayCache(capacity=8)
        fp = "f" * 8
        server.prepare_split(
            calls, plan, "c0", fp, carried_pairs=pairs
        )
        key = f"{fp}|{plan.signature()}"
        assert key in cache
        path = str(tmp_path / "cache.json")
        cache.save(path)

        fresh = ReplayCache()
        fresh.load(path)
        meta = fresh.known_metadata(key)
        assert meta is not None
        assert meta["plan"] == plan.signature()
        assert meta["carried_pairs"] == [[int(i), int(j)] for i, j in pairs]

        # a restarted server rebuilds the executable stateful from metadata
        # (the adopting client recorded one round: it passes no pairs)
        from repro.core.costmodel import GTX_2080TI
        from repro.core.engine import OffloadServer

        cold = OffloadServer(GTX_2080TI, execute=True, replay_cache=fresh)
        cold.context("c0").env.update(
            sess.server.context(sess.client_id).env
        )
        cold.prepare_split(calls, plan, "c0", fp, carried_pairs=())
        bound = cold.context("c0").split
        assert bound.program.is_stateful
        assert bound.program.carried_pairs == pairs
        assert bound.program.plan.signature() == plan.signature()
        assert bound.carried_state is not None  # seeded from the env
        server.replay_cache = None


class TestStatefulSegmentBatching:
    def test_cotenant_stateful_split_batches_and_isolates_state(self):
        """Two stateful split co-tenants on one shared IOS batch their
        server suffix on the GPU (seg_batches grows) while their per-client
        carried states evolve independently and correctly."""
        from repro.serving.multitenant import RRTOEdgeServer

        model = make_recurrent_sensor_decoder(
            scale=0.25, input_size=32, n_blocks=2, d_state=32
        )
        edge = RRTOEdgeServer(execute=True)
        cfg = PartitionConfig(adaptive=False)
        sessions = [
            edge.connect(model, min_repeats=2, partition=cfg)
            for _ in range(2)
        ]
        rng = np.random.default_rng(9)
        frames = {
            s.client_id: np.asarray(model.example_inputs[0])
            + rng.normal(0, 0.02, np.shape(model.example_inputs[0])).astype(
                np.float32
            )
            for s in sessions
        }
        h0 = np.asarray(model.example_inputs[1])
        states = {s.client_id: h0 for s in sessions}
        for _ in range(5):
            results = edge.run_round(
                {c: (frames[c], states[c]) for c in states}
            )
            for c in states:
                states[c] = results[c].outputs[1]
        assert all(s.client.mode == "replaying" for s in sessions)
        assert all(s.client.stateful_replay for s in sessions)
        pairs = sessions[0].client.ios.carried_pairs
        graph = SegmentGraph(
            sessions[0].client._ios_calls, carried_pairs=pairs
        )
        plan = feasible_plans(graph)[-1]
        for s in sessions:
            s.client._install_plan(plan)
        batches0 = edge.batcher.seg_batches
        for _ in range(3):
            results = edge.run_round(
                {c: (frames[c], states[c]) for c in states}
            )
            for c in states:
                states[c] = results[c].outputs[1]
        assert edge.batcher.seg_batches >= batches0 + 1
        # per-client trajectories match the local reference
        f = jax.jit(model.apply)
        for s in sessions:
            cid = s.client_id
            state = jnp.asarray(h0)
            for _ in range(8):
                y, state = f(model.params, frames[cid], state)
            np.testing.assert_allclose(
                np.asarray(results[cid].outputs[0]), np.asarray(y),
                rtol=1e-5, atol=1e-5,
            )
