"""Stateful replay: loop-carried tensor detection, donation-aware replay
executables (state server-resident, off the wire), O(1) decode-step serving,
fallback state materialization, carried-aware partition accounting, and
persistence of the donation binding across server restarts."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.offload import OffloadableModel, OffloadSession
from repro.core.opseq import detect_loop_carried
from repro.serving.multitenant import RRTOEdgeServer
from repro.serving.replay_cache import ReplayCache


def make_rnn(seed=0, d=8, batch=2):
    """A recurrent app threading explicit state: apply(p, x, state) ->
    [y, new_state] — the minimal loop-carried shape."""
    rng = np.random.default_rng(seed)
    params = {"w": rng.normal(0, 0.1, (d, d)).astype(np.float32)}

    def apply(p, x, state):
        new_state = jnp.tanh(state @ p["w"] + x)
        return [new_state.sum(axis=1), new_state]

    x = rng.normal(0, 1, (batch, d)).astype(np.float32)
    state0 = np.zeros((batch, d), np.float32)
    return OffloadableModel(f"rnn{seed}", apply, params, (x, state0)), x, state0


def drive(sess, x, state, steps):
    """Thread the state through ``steps`` inferences; returns history of
    (result, state-as-returned)."""
    hist = []
    for _ in range(steps):
        res = sess.infer(x, state)
        state = res.outputs[1]
        hist.append(res)
    return hist, state


def reference_trajectory(model, x, state0, steps):
    f = jax.jit(model.apply)
    state = jnp.asarray(state0)
    ys = []
    for _ in range(steps):
        y, state = f(model.params, x, state)
        ys.append(np.asarray(y))
    return ys


class TestCarriedDetection:
    def test_detects_state_pair(self):
        model, x, state0 = make_rnn()
        sess = OffloadSession(model, "rrto", min_repeats=3)
        sess.load()
        drive(sess, x, state0, 4)
        ios = sess.client.ios
        assert ios is not None
        assert ios.carried_pairs == ((1, 1),)
        # replay RPCs drop to wire-only traffic
        assert ios.num_rpcs_replayed == 2

    def test_stateless_app_detects_nothing(self):
        rng = np.random.default_rng(0)
        params = {"w": rng.normal(0, 0.1, (8, 8)).astype(np.float32)}
        model = OffloadableModel(
            "mlp",
            lambda p, x: [jnp.tanh(x @ p["w"])],
            params,
            (rng.normal(0, 1, (2, 8)).astype(np.float32),),
        )
        sess = OffloadSession(model, "rrto", min_repeats=3)
        sess.load()
        x = np.asarray(model.example_inputs[0])
        for _ in range(5):
            sess.infer(x)
        ios = sess.client.ios
        assert ios is not None and ios.carried_pairs == ()

    def test_single_round_log_detects_nothing(self):
        """A one-round log (cache adoption) cannot detect pairs itself."""
        model, x, state0 = make_rnn()
        sess = OffloadSession(model, "rrto", min_repeats=3)
        sess.load()
        drive(sess, x, state0, 4)
        ios = sess.client.ios
        one_round = list(
            sess.client.calls[ios.start_index : ios.start_index + len(ios)]
        )
        import dataclasses

        solo = dataclasses.replace(ios, start_index=0, carried_pairs=())
        assert detect_loop_carried(one_round, solo) == ()


class TestStatefulReplayExecution:
    def test_outputs_track_reference(self):
        """Server-resident state advances correctly even though the app only
        threads opaque handles once replay starts."""
        model, x, state0 = make_rnn()
        sess = OffloadSession(model, "rrto", min_repeats=3)
        sess.load()
        steps = 10
        hist, _ = drive(sess, x, state0, steps)
        refs = reference_trajectory(model, x, state0, steps)
        for res, ref in zip(hist, refs):
            np.testing.assert_allclose(
                np.asarray(res.outputs[0]), ref, rtol=1e-6, atol=1e-6
            )
        assert hist[-1].mode == "replaying"

    def test_state_never_crosses_after_handoff(self):
        """Steady-state replay ships only the wire input/output: the carried
        state contributes zero network bytes and zero RPCs."""
        model, x, state0 = make_rnn(d=64)
        sess = OffloadSession(model, "rrto", min_repeats=3)
        sess.load()
        hist, _ = drive(sess, x, state0, 10)
        replaying = [r for r in hist if r.mode == "replaying"]
        first, steady = replaying[0], replaying[1:]
        assert steady, "never reached steady replay"
        state_bytes = np.asarray(state0).nbytes
        for r in steady:
            assert r.rpcs == 2  # x upload + y download only
            # vs the handoff round (which shipped the state once): at least
            # the state bytes vanished from the wire
            assert r.network_bytes <= first.network_bytes - state_bytes

    def test_fresh_state_reships_once(self):
        """Supplying genuinely new state (not the threaded handle) pays one
        upload and overwrites the server-resident state — the app can reset
        its sequence mid-session."""
        model, x, state0 = make_rnn()
        sess = OffloadSession(model, "rrto", min_repeats=3)
        sess.load()
        hist, _ = drive(sess, x, state0, 8)
        steady_rpcs = hist[-1].rpcs
        # reset: feed a brand-new state array
        fresh = np.full_like(state0, 0.25)
        res = sess.infer(x, fresh)
        assert res.rpcs == steady_rpcs + 1  # the one-time state upload
        ref_y, _ = jax.jit(model.apply)(model.params, x, jnp.asarray(fresh))
        np.testing.assert_allclose(
            np.asarray(res.outputs[0]), np.asarray(ref_y),
            rtol=1e-6, atol=1e-6,
        )

    def test_multitenant_stateful_batched(self):
        """Co-tenant recurrent apps replay as one vmap-batched stateful step;
        per-client state trajectories stay isolated and correct."""
        model, x, state0 = make_rnn()
        edge = RRTOEdgeServer(execute=True)
        n = 3
        for _ in range(n):
            edge.connect(model)
        ids = list(edge.sessions)
        rng = np.random.default_rng(7)
        xs = {c: rng.normal(0, 1, x.shape).astype(np.float32) for c in ids}
        states = {c: state0 for c in ids}
        rounds = 8
        for _ in range(rounds):
            results = edge.run_round(
                {c: (xs[c], states[c]) for c in ids}
            )
            for c in ids:
                states[c] = results[c].outputs[1]
        f = jax.jit(model.apply)
        for c in ids:
            state = jnp.asarray(state0)
            for _ in range(rounds):
                y, state = f(model.params, xs[c], state)
            np.testing.assert_allclose(
                np.asarray(results[c].outputs[0]), np.asarray(y),
                rtol=1e-6, atol=1e-6,
            )
        assert edge.batcher.vmap_batches >= 1
        assert edge.compile_count == 1


class TestPayloadRetention:
    def test_searchless_client_drops_old_payloads(self):
        """A client that never locks an IOS (cricket: no search) must not pin
        every transferred tensor forever — payloads are kept only on the
        trailing detection horizon."""
        from repro.core.engine import PAYLOAD_RETENTION_CALLS

        rng = np.random.default_rng(0)
        params = {"w": rng.normal(0, 0.1, (8, 8)).astype(np.float32)}
        model = OffloadableModel(
            "mlp",
            lambda p, x: [jnp.tanh(x @ p["w"])],
            params,
            (rng.normal(0, 1, (2, 8)).astype(np.float32),),
        )
        sess = OffloadSession(model, "cricket", execute=False)
        sess.load()
        x = np.asarray(model.example_inputs[0])
        while len(sess.client.calls) <= PAYLOAD_RETENTION_CALLS + 100:
            sess.infer(x)
        calls = sess.client.calls
        old = calls[: len(calls) - PAYLOAD_RETENTION_CALLS - 1]
        assert all(
            c.h2d_value is None and c.d2h_value is None for c in old
        )
        # recent payloads (the detection horizon) are still live
        assert any(
            c.h2d_value is not None
            for c in calls[-PAYLOAD_RETENTION_CALLS:]
        )

    def test_long_ios_keeps_detection_horizon(self, monkeypatch):
        """A framework-noise-heavy app can blow through the call-count
        payload horizon inside ~2 inferences; the trailing *transfer*
        payloads must survive anyway or loop-carried detection silently
        fails (regression: detection needs ~3 repeats of h2d/d2h values)."""
        import repro.core.engine as eng

        monkeypatch.setattr(eng, "PAYLOAD_RETENTION_CALLS", 40)
        monkeypatch.setattr(eng, "PAYLOAD_RETENTION_TRANSFERS", 16)
        model, x, state0 = make_rnn()
        sess = OffloadSession(model, "rrto", min_repeats=3)
        sess.load()
        drive(sess, x, state0, 5)
        ios = sess.client.ios
        assert ios is not None
        # the IOS is longer than the call horizon, yet the pairs were found
        assert len(ios) * 2 > 40
        assert ios.carried_pairs == ((1, 1),)

    def test_detection_survives_in_place_mutation(self):
        """An app that mutates a downloaded output in place before
        re-uploading it must NOT be classified loop-carried (the recorded
        download is a snapshot, not an alias)."""
        rng = np.random.default_rng(0)
        params = {"w": rng.normal(0, 0.1, (8, 8)).astype(np.float32)}

        def apply(p, x, state):
            return [x @ p["w"] + state]

        x = rng.normal(0, 1, (2, 8)).astype(np.float32)
        state0 = np.zeros((2, 8), np.float32)
        model = OffloadableModel("mut", apply, params, (x, state0))
        # execute=False returns writable buffers, letting the app mutate the
        # very array the recorder would otherwise have aliased
        sess = OffloadSession(model, "rrto", min_repeats=3, execute=False)
        sess.load()
        state = state0
        for _ in range(6):
            res = sess.infer(x, state)
            out = np.asarray(res.outputs[0])
            out += 1.0          # in-place post-processing by the app
            state = out         # re-upload the mutated buffer
        ios = sess.client.ios
        assert ios is not None
        assert ios.carried_pairs == ()  # mutated: genuinely new state


class TestFallbackMaterialization:
    def test_dam_deviation_downloads_state(self):
        """Deviating from a stateful IOS (shape change) downloads the real
        carried state for catch-up and keeps results correct afterwards."""
        model, x, state0 = make_rnn()
        sess = OffloadSession(model, "rrto", min_repeats=3)
        sess.load()
        hist, state = drive(sess, x, state0, 8)
        assert hist[-1].mode == "replaying"
        client = sess.client
        assert client.fallbacks == 0
        # the app's held handle must now be materializable: deviate by
        # running one inference whose INPUT value is fine but force a
        # mid-walk deviation via a different x shape? shapes are fixed by
        # the jaxpr — instead check the materializer directly
        bound = sess.server.context(client.client_id).replay
        ref_state = np.asarray(bound.carried_state[0])
        # the handle the app holds is stale; materialization must fetch the
        # live value
        ph = client._carried_placeholders[0]
        assert not np.array_equal(ph, ref_state)
        client._replay_prefix = [
            c for c in client._ios_calls if c.record.func == "cudaMemcpyHtoD"
        ]
        # point the prefix handles at what the app would actually resend
        client._replay_prefix[1].h2d_value = ph
        rpcs_before = client.stats.rpcs
        client._materialize_carried_prefix()
        assert client.stats.rpcs == rpcs_before + 1
        np.testing.assert_array_equal(
            np.asarray(client._replay_prefix[1].h2d_value), ref_state
        )
        # the app-held handle was updated in place
        np.testing.assert_array_equal(ph, ref_state)


class TestPartitionCarriedAccounting:
    def test_carried_excluded_from_cut_costs(self):
        from repro.partition.segments import SegmentGraph

        model, x, state0 = make_rnn()
        sess = OffloadSession(model, "rrto", min_repeats=3)
        sess.load()
        drive(sess, x, state0, 4)
        client = sess.client
        calls = client._ios_calls
        plain = SegmentGraph(calls)
        carried = SegmentGraph(
            calls, carried_input_ordinals=[i for i, _ in client.ios.carried_pairs]
        )
        assert carried.carried_tids
        # every boundary's live (wire-crossing) volume shrinks by at least
        # the carried state bytes wherever the state was live
        state_bytes = np.asarray(state0).nbytes
        lp, lc = plain.live_bytes(), carried.live_bytes()
        assert any(a - b >= state_bytes for a, b in zip(lp, lc))
        assert all(a >= b for a, b in zip(lp, lc))

    def test_stateful_client_keeps_carried_feasible_planner(self):
        """A stateful IOS no longer disables the planner: the client plans
        over a carried-aware graph, any installed plan is carried-feasible
        (trailing server segment holding every state-touching op), and the
        replayed outputs stay correct."""
        from repro.partition.planner import PartitionConfig

        model, x, state0 = make_rnn()
        sess = OffloadSession(
            model, "rrto", min_repeats=3, partition=PartitionConfig()
        )
        sess.load()
        steps = 10
        hist, _ = drive(sess, x, state0, steps)
        assert hist[-1].mode == "replaying"
        client = sess.client
        assert client.replanner is not None
        assert client.replanner.graph.is_stateful
        plan = client.replanner.current.plan
        assert client.replanner.graph.plan_carried_feasible(plan)
        if client.split_plan is not None:
            assert not client.split_plan.is_full_device
        refs = reference_trajectory(model, x, state0, steps)
        for res, ref in zip(hist, refs):
            np.testing.assert_allclose(
                np.asarray(res.outputs[0]), ref, rtol=1e-6, atol=1e-6
            )


class TestStatefulPersistence:
    def test_restart_rebuilds_donation_binding(self, tmp_path):
        """Save/load roundtrip with a stateful entry: the restarted server
        skips re-validation AND rebuilds the executable stateful (carried
        pairs recovered from metadata), so the adopting client immediately
        replays O(1) with the state off the wire."""
        model, x, state0 = make_rnn()
        warm = RRTOEdgeServer(execute=True)
        warm.connect(model)
        state = state0
        for _ in range(5):
            res = warm.run_round({"c0": (x, state)})["c0"]
            state = res.outputs[1]
        fp = warm.cache.fingerprints[0]
        meta_path = str(tmp_path / "cache.json")
        warm.save_cache(meta_path)

        cold = RRTOEdgeServer(execute=True)
        cold.load_cache(meta_path)
        meta = cold.cache.known_metadata(fp)
        assert meta["carried_pairs"] == [[1, 1]]

        sess = cold.connect(model)
        state = state0
        hist = []
        for _ in range(6):
            res = cold.run_round({"c0": (x, state)})["c0"]
            state = res.outputs[1]
            hist.append(res)
        client = sess.client
        assert client.cache_adopted
        assert sum(1 for r in hist if r.mode == "recording") == 1
        program = cold.server.context("c0").replay.program
        assert program.is_stateful and program.carried_pairs == ((1, 1),)
        # steady state: wire-only RPCs, correct values
        assert hist[-1].rpcs == 2
        refs = reference_trajectory(model, x, state0, 6)
        np.testing.assert_allclose(
            np.asarray(hist[-1].outputs[0]), refs[-1], rtol=1e-6, atol=1e-6
        )

    def test_segmented_and_stateful_entries_roundtrip(self, tmp_path):
        """The cache file carries both a segmented (fingerprint|plan) entry
        and a stateful entry; both identities survive the restart."""
        from repro.core.offload import OffloadSession
        from repro.partition.planner import PartitionConfig

        # a stateless model forced through a split plan -> segmented entry
        rng = np.random.default_rng(3)
        params = {
            "w1": rng.normal(0, 0.1, (64, 64)).astype(np.float32),
            "w2": rng.normal(0, 0.1, (64, 64)).astype(np.float32),
        }

        def apply(p, xx):
            h = jnp.tanh(xx @ p["w1"])
            return [jnp.tanh(h @ p["w2"])]

        xx = rng.normal(0, 1, (4, 64)).astype(np.float32)
        split_model = OffloadableModel("mlp", apply, params, (xx,))

        edge = RRTOEdgeServer(execute=True, environment="outdoor")
        sess = edge.connect(
            split_model, min_repeats=3, partition=PartitionConfig()
        )
        for _ in range(6):
            edge.run_round({"c0": (xx,)})
        # a stateful tenant on the same box
        rnn_model, x, state0 = make_rnn()
        sess2 = edge.connect(rnn_model, min_repeats=3)
        state = state0
        for _ in range(5):
            res = edge.run_round({"c1": (x, state)})["c1"]
            state = res.outputs[1]

        path = str(tmp_path / "cache.json")
        n = edge.save_cache(path)
        assert n == len(edge.cache)
        keys = edge.cache.fingerprints
        assert not any("#" in k for k in keys)  # no derived vmap entries

        fresh = ReplayCache()
        assert fresh.load(path) == n
        segmented = [
            k for k in fresh.persisted_fingerprints if "|" in k
        ]
        stateful = [
            k
            for k in fresh.persisted_fingerprints
            if fresh.known_metadata(k).get("carried_pairs")
        ]
        if sess.client.split_plan is not None:
            assert segmented, "split plan produced no segmented entry"
            assert "plan" in fresh.known_metadata(segmented[0])
        assert stateful and fresh.known_metadata(stateful[0])[
            "carried_pairs"
        ] == [[1, 1]]
        assert sess2.client.stateful_replay


class TestSizeAwareCache:
    class _P:
        def __init__(self, nbytes):
            self.nbytes_estimate = nbytes

    def test_evicts_by_bytes(self):
        cache = ReplayCache(capacity=8, capacity_bytes=1000)
        cache.put("a", self._P(400))
        cache.put("b", self._P(400))
        assert cache.bytes_total == 800
        cache.put("c", self._P(400))     # 1200 > 1000 -> evict LRU (a)
        assert "a" not in cache and "b" in cache and "c" in cache
        assert cache.stats.evictions == 1
        assert cache.stats.bytes_evicted == 400

    def test_pinned_entries_survive(self):
        cache = ReplayCache(capacity=8, capacity_bytes=1000)
        cache.put("a", self._P(400))
        cache.pin("a")
        cache.put("b", self._P(400))
        cache.put("c", self._P(400))     # must evict b, not pinned a
        assert "a" in cache and "b" not in cache and "c" in cache

    def test_pin_covers_derived_entries(self):
        cache = ReplayCache(capacity=8, capacity_bytes=1000)
        cache.pin("fp")
        cache.put("fp", self._P(300))
        cache.put("fp|D0:1|S1:4", self._P(300))
        cache.put("fp#vmap4", self._P(300))
        cache.put("other", self._P(300))   # over budget: only victim
        assert "other" not in cache
        assert all(
            k in cache for k in ("fp", "fp|D0:1|S1:4", "fp#vmap4")
        )

    def test_unpin_reenables_eviction(self):
        cache = ReplayCache(capacity=8, capacity_bytes=500)
        cache.pin("a")
        cache.put("a", self._P(400))
        cache.put("b", self._P(400))     # denied: everything else is pinned
        assert "a" in cache and "b" not in cache
        cache.unpin("a")
        cache.put("b", self._P(400))     # now a is fair game
        assert "b" in cache and "a" not in cache

    def test_oversized_entry_stays_alone(self):
        cache = ReplayCache(capacity=8, capacity_bytes=100)
        cache.put("big", self._P(5000))
        assert "big" in cache            # never evict the sole entry

    def test_entry_count_capacity_still_applies(self):
        cache = ReplayCache(capacity=2)
        for k in "abc":
            cache.put(k, self._P(10))
        assert "a" not in cache and len(cache) == 2

    def test_derived_vmap_entries_never_evict_base_programs(self):
        """Per-width batched executables pile up (stateful lockstep shrinks
        the width as clients finish); they must be evicted before any base
        program or an adopting client would recompile and break
        program-identity sharing."""
        cache = ReplayCache(capacity=4)
        cache.put("fpA", self._P(10))
        cache.put("fpB", self._P(10))
        for w in (2, 3, 4):
            cache.put(f"fpA#vmap{w}", self._P(10))   # over entry capacity
        assert "fpA" in cache and "fpB" in cache     # bases survived
        assert sum(1 for k in cache.fingerprints if "#" in k) == 2

    def test_evicting_base_purges_its_derived_entries(self):
        cache = ReplayCache(capacity=8, capacity_bytes=100)
        cache.put("fpA", self._P(40))
        cache.put("fpA#vmap2", self._P(10))
        cache.put("fpB", self._P(80))   # evicts vmap first, then fpA
        assert "fpA" not in cache and "fpA#vmap2" not in cache
        assert "fpB" in cache

    def test_claimed_derived_entry_pins_base(self):
        """A claim on a derived key (an in-flight batch round executing a
        vmap/segmented executable) pins the BASE entry: eviction pressure
        must not purge the base — and the derived entry with it — until the
        round releases the claim."""
        cache = ReplayCache(capacity=8, capacity_bytes=1000)
        cache.put("fp", self._P(400))
        cache.claim("fp#vmap4")              # round starts executing
        cache.put("fp#vmap4", self._P(300))
        cache.put("other", self._P(400))     # over budget
        assert "fp" in cache and "fp#vmap4" in cache  # base survived
        cache.release("fp#vmap4")            # round over: fp evictable again
        cache.put("other2", self._P(400))    # derived entries evict first
        assert "fp#vmap4" not in cache and "fp" in cache
        cache.put("other3", self._P(400))    # now the base is the LRU victim
        assert "fp" not in cache

    def test_claims_nest_and_cover_stream_executor_keys(self):
        """Claims refcount, and the pipelined stream executor's derived
        ``fp|plan`` key pins the same base as a vmap key would."""
        cache = ReplayCache(capacity=8, capacity_bytes=800)
        cache.put("fp", self._P(400))
        cache.claim("fp|D0:1|S1:4")          # stream executor installed
        cache.claim("fp#vmap2")              # plus an in-flight batch
        cache.put("big", self._P(700))       # pressure
        assert "fp" in cache                 # pinned by both claims
        cache.release("fp#vmap2")
        cache.put("big", self._P(700))
        assert "fp" in cache                 # stream claim still held
        cache.release("fp|D0:1|S1:4")
        cache.put("big", self._P(700))
        assert "fp" not in cache             # all claims gone

    def test_batcher_round_claims_protect_in_flight_bases(self):
        """Integration: while a round with a derived-key group is in flight,
        cache pressure cannot evict the base; the next begin_round releases
        the claims."""
        from repro.core.costmodel import GTX_2080TI
        from repro.core.engine import OffloadServer
        from repro.serving.multitenant import ReplayBatcher

        cache = ReplayCache(capacity=8, capacity_bytes=1000)
        server = OffloadServer(GTX_2080TI, execute=False, replay_cache=cache)
        batcher = ReplayBatcher(server)
        cache.put("fp", self._P(400))
        batcher.begin_round({"fp|D0:2|S2:9": []})
        cache.put("other", self._P(400))
        cache.put("other2", self._P(400))     # pressure: 1200 > 1000
        assert "fp" in cache                  # claimed base survived
        batcher.begin_round({})               # round over, claims released
        cache.put("other3", self._P(400))
        assert "fp" not in cache


class TestBatcherInputDigest:
    def test_mixed_shape_cotenants_fall_to_solo(self):
        """A submission whose inputs mismatch its preload (shape drift mid
        window) is rejected by the cheap digest compare and replays solo —
        regression for the full-array-compare-per-submit hot path."""
        from repro.serving.multitenant import _BatchGroup, _inputs_equal

        a = [np.zeros((2, 8), np.float32)]
        b = [np.zeros((4, 8), np.float32)]
        assert not _inputs_equal(a, b)
        assert _inputs_equal(a, [np.zeros((2, 8), np.float32)])
        group = _BatchGroup(done_at=0.0, pending={"c0": a})
        assert not group.claim("c0", b)
        assert not group.claim("c0", b)  # popped: second claim is a miss

    def test_digest_short_circuits_value_compare(self, monkeypatch):
        import repro.serving.multitenant as mt

        calls = {"n": 0}
        real = np.array_equal

        def counting(x, y):
            calls["n"] += 1
            return real(x, y)

        monkeypatch.setattr(mt.np, "array_equal", counting)
        a = [np.zeros((2, 8), np.float32)]
        b = [np.zeros((4, 8), np.float32)]
        assert not mt._inputs_equal(a, b)
        assert calls["n"] == 0           # digest rejected before any compare
