"""Training substrate: optimizer correctness, chunked loss == dense loss,
memorization on a fixed batch, data-stream determinism."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import lm
from repro.training.data import DataConfig, synth_batch
from repro.training.losses import chunked_lm_loss
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state
from repro.training.step import init_train_state, make_loss_fn, make_train_step

CFG = ArchConfig(
    name="t", family="dense", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_head=16, d_ff=128, vocab=256, dtype="float32", rope_theta=1e4,
)
SHAPE = ShapeConfig("t", 32, 4, "train")


class TestChunkedLoss:
    @pytest.mark.parametrize("chunk_len", [7, 16, 32, 256])
    def test_matches_dense(self, chunk_len):
        params = lm.init_params(jax.random.PRNGKey(0), CFG)
        batch = synth_batch(CFG, SHAPE, 0, DataConfig())
        dense_loss = lm.loss_fn(params, batch, CFG, remat=False)
        h = lm.forward(params, batch, CFG, return_hidden=True)
        chunked = chunked_lm_loss(
            h, params["final_norm"], lm.head_weights(params, CFG),
            jnp.asarray(batch["labels"]), CFG, chunk_len=chunk_len,
        )
        np.testing.assert_allclose(
            float(chunked), float(dense_loss), rtol=1e-5, atol=1e-6
        )

    def test_gradients_match(self):
        params = lm.init_params(jax.random.PRNGKey(0), CFG)
        batch = synth_batch(CFG, SHAPE, 0, DataConfig())
        g_dense = jax.grad(lambda p: lm.loss_fn(p, batch, CFG, remat=False))(params)
        g_chunk = jax.grad(make_loss_fn(CFG, remat=False))(params, batch)
        for a, b in zip(jax.tree.leaves(g_dense), jax.tree.leaves(g_chunk)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)


class TestOptimizer:
    def test_adamw_moves_toward_minimum(self):
        params = {"w": jnp.array([3.0, -2.0])}
        opt = init_opt_state(params)
        cfg = AdamWConfig(lr=0.1, warmup_steps=1, weight_decay=0.0)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, opt, _ = adamw_update(grads, opt, params, cfg)
        assert float(jnp.abs(params["w"]).max()) < 1e-2

    def test_grad_clip(self):
        params = {"w": jnp.zeros(4)}
        opt = init_opt_state(params)
        cfg = AdamWConfig(lr=1.0, warmup_steps=1, grad_clip=1.0, weight_decay=0.0)
        _, _, gnorm = adamw_update({"w": jnp.full(4, 100.0)}, opt, params, cfg)
        assert float(gnorm) == pytest.approx(200.0)

    def test_memorizes_fixed_batch(self):
        params, opt = init_train_state(CFG)
        step = jax.jit(make_train_step(CFG, AdamWConfig(lr=3e-3, warmup_steps=1)))
        batch = synth_batch(CFG, SHAPE, 0, DataConfig())
        losses = []
        for _ in range(25):
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 1.0, losses[::6]


class TestData:
    def test_stream_deterministic(self):
        a = synth_batch(CFG, SHAPE, 7, DataConfig(seed=3))
        b = synth_batch(CFG, SHAPE, 7, DataConfig(seed=3))
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_steps_differ(self):
        a = synth_batch(CFG, SHAPE, 1, DataConfig())
        b = synth_batch(CFG, SHAPE, 2, DataConfig())
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_process_sharding(self):
        full = synth_batch(CFG, SHAPE, 0, DataConfig(process_count=1))
        half = synth_batch(CFG, SHAPE, 0, DataConfig(process_count=2))
        assert half["tokens"].shape[0] == full["tokens"].shape[0] // 2
